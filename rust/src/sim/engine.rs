//! The simulation engine: wires the trace stream, routing, queue manager,
//! schedulers, autoscalers, forecaster and metrics into one
//! discrete-event loop.
//!
//! Event cadence:
//! * request arrivals — merged lazily from the streaming trace iterator
//!   (the heap never holds the trace);
//! * `ChunkDone` — instance decode-chunk boundaries;
//! * `ProvisionDone` — instance becomes Active;
//! * `ScaleTick` (15 s) — reactive/LT-U/LT-UA/Chiron checks, NIW release
//!   signals, utilization sampling;
//! * `ControlEpoch` (hourly) — forecast + ILP (LT strategies);
//! * `QmTick` (60 s) — NIW aging scan.
//!
//! ## Resumable execution
//!
//! The event loop is exposed in two granularities sharing one code path:
//! [`Simulation::run`] drives the whole trace, while
//! [`Simulation::run_chunk`] + [`Simulation::finish`] drive it one
//! arrival slice at a time with identical pop ordering — the foundation
//! of the epoch-sliced chunked executor in [`crate::sim::chunked`].
//! Between chunks the complete mutable state can be carried across as an
//! explicit [`SimHandoff`] via [`Simulation::suspend`] /
//! [`Simulation::resume`], which is how the chunked executor proves the
//! handoff covers everything: chunked runs are *bit-identical* to
//! sequential ones (`tests/chunked_equivalence.rs`).

use std::collections::BTreeMap;

use crate::config::{
    DisaggParams, Epoch, FleetSpec, GpuKind, GuardrailParams, ModelKind, Region, RoutingParams,
    ScalingParams, Tier, Time, HOUR, MINUTE,
};
pub use crate::coordinator::autoscaler::Strategy;
use crate::coordinator::autoscaler::{Autoscaler, ScaleCtx};
use crate::coordinator::controller::{
    guardrail_epoch, run_epoch, run_epoch_disagg, run_epoch_modded, ControlEpochMods,
    GuardrailState, SolverStates, Telemetry,
};
use crate::coordinator::queue_manager::QueueManager;
use crate::coordinator::router;
use crate::coordinator::scheduler::SchedPolicy;
use crate::forecast::{Forecaster, NativeArForecaster};
use crate::metrics::{GuardrailMode, Metrics, MetricsConfig};
use crate::perf::PerfTable;
use crate::sim::cluster::{Cluster, InstanceId};
use crate::sim::event::{Event, EventQueue};
use crate::sim::faults::{ControlFaultPlan, FaultPlan};
use crate::sim::instance::{InstState, Phase};
use crate::trace::generator::{TraceConfig, TraceGenerator};
use crate::trace::types::Request;

/// Simulation parameters.
pub struct SimConfig {
    /// Workload: models, regions, epoch shape, scale and seed.
    pub trace: TraceConfig,
    /// GPU fleet: which SKUs the cluster provisions and how the initial
    /// allocation splits across them (§5's k axis; single-SKU fleets
    /// reproduce the paper's homogeneous experiments exactly).
    pub fleet: FleetSpec,
    /// Auto-scaling strategy under test (§4/§6).
    pub strategy: Strategy,
    /// Per-instance admission ordering (EDF by default).
    pub sched_policy: SchedPolicy,
    /// Scaling thresholds, control interval, NIW release/aging knobs.
    pub scaling: ScalingParams,
    /// Region/SKU routing thresholds and cross-region latency model.
    pub routing: RoutingParams,
    /// Instances per (model, region) at t=0 (§7.1: 20).
    pub initial_instances: usize,
    /// Spare VMs per region beyond the initial allocation.
    pub vm_budget: usize,
    /// Use the PJRT-compiled forecaster (requires `make artifacts`);
    /// otherwise the native Rust replica of the same pipeline.
    pub pjrt_forecaster: bool,
    pub artifacts_dir: String,
    /// Replay an external CSV trace instead of generating one (the
    /// published-trace path; `trace` config still provides the forecaster
    /// warm-up rates and the drain horizon via `days`).
    pub replay_trace: Option<std::path::PathBuf>,
    /// Replay a pre-materialized arrival buffer instead of streaming the
    /// generator (the sweep path: one generation shared across every
    /// strategy run — see `experiments::sweep::share_traces`).  Must be
    /// byte-identical to what `trace` would generate; `trace` still
    /// drives forecaster warm-up and the drain horizon.
    pub shared_trace: Option<std::sync::Arc<[Request]>>,
    /// Metrics recording mode and bin width.  The default (streaming,
    /// 15-minute bins) keeps peak memory O(bins); `MetricsMode::Exact`
    /// additionally logs every `RequestOutcome` for fidelity work
    /// (`simulate --metrics exact`).
    pub metrics: MetricsConfig,
    /// Deterministic fault schedule (region outages, VM-crash hazard,
    /// spot preemption shocks, latency degradation).  The default is the
    /// empty plan: it compiles to zero events and the engine's fault
    /// paths never run, so fault-free runs stay bit-identical to builds
    /// without the fault plane.
    pub faults: FaultPlan,
    /// Prefill/decode disaggregation (§2.3 phase split).  Disabled by
    /// default: every gate in the engine checks `disagg.enabled`, so the
    /// unified path executes byte-identical float operations and runs
    /// stay bit-identical to pre-disaggregation builds
    /// (`tests/disagg_equivalence.rs`).
    pub disagg: DisaggParams,
    /// Deterministic **control-plane** fault schedule (forecast
    /// blackout/corruption, telemetry freezes, solver failures,
    /// actuation drop/delay).  Unlike [`FaultPlan`] this compiles to no
    /// events at all — it is a set of pure window predicates the engine
    /// samples at each control epoch and scale tick.  The default (the
    /// empty plan) keeps every sampled modifier at its identity value,
    /// so runs stay bit-identical to pre-guardrail builds
    /// (`tests/guardrail_equivalence.rs`).
    pub control_faults: ControlFaultPlan,
    /// Guardrail controller (watchdog + residual tracker + fallback
    /// cascade) for forecast-driven strategies.  Off by default: the
    /// naive controller runs, faulted inputs and all.  Ignored on
    /// disaggregated fleets (the cascade covers the unified control
    /// path).
    pub guardrails: GuardrailParams,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            trace: TraceConfig::default(),
            fleet: FleetSpec::default(),
            strategy: Strategy::LtUa,
            sched_policy: SchedPolicy::Edf,
            scaling: ScalingParams::default(),
            routing: RoutingParams::default(),
            initial_instances: 20,
            vm_budget: 40,
            pjrt_forecaster: false,
            artifacts_dir: "artifacts".to_string(),
            replay_trace: None,
            shared_trace: None,
            metrics: MetricsConfig::default(),
            faults: FaultPlan::default(),
            disagg: DisaggParams::default(),
            control_faults: ControlFaultPlan::default(),
            guardrails: GuardrailParams::default(),
        }
    }
}

const SCALE_TICK: Time = 15.0;
const UTIL_SAMPLE_EVERY: u64 = 60; // ticks → one util sample / 15 min

/// An open fault incident whose capacity recovery the engine is still
/// watching: when `region`'s active-instance count climbs back to
/// `target` (its pre-incident level), the incident's time-to-recover is
/// stamped.  Lives in the [`SimHandoff`] so chunked runs track recovery
/// across boundaries.
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryWatch {
    /// Index into `FaultPlan::outages` (matches start/end events).
    pub outage: usize,
    /// The region whose capacity must recover.
    pub region: Region,
    /// Pre-incident active-instance count to restore.
    pub target: usize,
    /// Index into `Metrics::failures.incidents`.
    pub incident: usize,
}

/// The simulation: build with [`Simulation::new`], run with
/// [`Simulation::run`], then read `metrics`.
pub struct Simulation {
    /// Current simulated time (seconds since trace start).
    pub now: Time,
    /// The configuration this simulation was built from.
    pub cfg: SimConfig,
    /// Regions, endpoints, instances and their O(1) aggregates.
    pub cluster: Cluster,
    /// Streaming result accumulator (latency bins, ledgers, counters).
    pub metrics: Metrics,
    /// Per-(model, region) observed-load window feeding the forecaster.
    pub telemetry: Telemetry,
    /// Global NIW queue manager (§6.2).
    pub qm: QueueManager,
    events: EventQueue,
    autoscaler: Autoscaler,
    forecaster: Box<dyn Forecaster>,
    /// Per-model ILP warm-start state, reused every control epoch.  On
    /// disaggregated fleets this holds the *prefill* column's state.
    solvers: SolverStates,
    /// Warm-start state for the decode-phase capacity solves (the θ
    /// columns differ per phase, so warm bases never cross phases).
    /// Unused — and empty — on unified fleets.
    solvers_decode: SolverStates,
    end_time: Time,
    epoch_start: Time,
    tick_count: u64,
    /// Reused per-epoch buffer of per-SKU allocated counts, rows in
    /// `telemetry.keys()` order — no per-epoch map/Vec allocation.
    /// On disaggregated fleets this holds the prefill-pool counts.
    epoch_counts: Vec<[usize; GpuKind::COUNT]>,
    /// Decode-pool counterpart of `epoch_counts` (scratch, same
    /// lifecycle).  Empty on unified fleets.
    epoch_counts_decode: Vec<[usize; GpuKind::COUNT]>,
    /// Requests killed by instance loss, parked between their kill and
    /// their `RetryDue` event (keyed by request id — the event carries
    /// only the key, keeping `Event: Eq` trivial).
    pending_retries: BTreeMap<u64, Request>,
    /// Kill count per in-flight request id (drives the capped
    /// exponential backoff; entries are dropped on completion or loss).
    retry_attempt: BTreeMap<u64, u32>,
    /// Disaggregation: requests whose prefill finished, parked between
    /// the KV-transfer start and their `HandoffDue` decode admission.
    /// Values carry the already-computed TTFT and the prefill region
    /// (decode placement prefers transfer-cheap targets near it).
    pending_handoffs: BTreeMap<u64, (Request, Time, Region)>,
    /// Disaggregation: TTFT of requests admitted to a decode instance,
    /// consumed when the decode completion records the outcome.
    inflight_decode: BTreeMap<u64, Time>,
    /// Open incidents awaiting capacity recovery.
    recovery_watch: Vec<RecoveryWatch>,
    /// Guardrail-controller state (residual window, last-good plan,
    /// cascade rung).  Inert unless `cfg.guardrails.enabled`; carried in
    /// the handoff so chunked guarded runs stay bit-identical.
    guardrail: GuardrailState,
}

/// Complete mutable simulator state, detached from a [`Simulation`] so it
/// can be carried across a chunk boundary (or, in principle, serialized
/// between processes).  Everything the event loop reads *and* writes is
/// here; re-attaching it to the same `SimConfig` via
/// [`Simulation::resume`] continues the run bit-identically.
///
/// Two `Simulation` fields are deliberately absent:
/// * `end_time` — derived from `cfg.trace.days`, recomputed on resume;
/// * `epoch_counts` / `epoch_counts_decode` — scratch buffers cleared at
///   the start of every control epoch, so empty ones are equivalent
///   state.
pub struct SimHandoff {
    /// Simulated clock at suspension.
    pub now: Time,
    /// Cluster allocation, per-endpoint aggregates and in-flight
    /// instance work (batches, waiting queues, KV accounting).
    pub cluster: Cluster,
    /// Metrics accumulator.  Carried, not merged: re-folding outcomes
    /// into the *same* accumulator in the same order is what makes
    /// chunked runs bit-identical (summing per-chunk f64 shards in a
    /// different association would only match within rounding — see the
    /// `Metrics::merge` contract).
    pub metrics: Metrics,
    /// Telemetry window (forecaster features), including warm-up.
    pub telemetry: Telemetry,
    /// NIW queue-manager depths and per-model FIFOs.
    pub qm: QueueManager,
    /// Pending event heap, moved wholesale — its internal sequence
    /// counter keeps same-time events popping in the original order.
    pub events: EventQueue,
    /// Strategy state machine (armed targets, progression state).
    pub autoscaler: Autoscaler,
    /// Forecaster state (AR model / PJRT executable handle).
    pub forecaster: Box<dyn Forecaster>,
    /// Per-model ILP warm-start state.  Carried so a resumed chunk's
    /// first control epoch re-solves warm exactly like the unchunked run
    /// (the plan is identical either way — warm starts change pivot
    /// counts, not answers — but carrying it keeps the perf contract).
    pub solvers: SolverStates,
    /// Decode-phase warm-start state (disaggregated fleets only; empty
    /// and inert on unified runs, carried for the same perf contract).
    pub solvers_decode: SolverStates,
    /// Start time of the current control epoch.
    pub epoch_start: Time,
    /// ScaleTick counter (drives the 15-minute utilization sampling).
    pub tick_count: u64,
    /// Fault plane: requests awaiting their `RetryDue` event.
    pub pending_retries: BTreeMap<u64, Request>,
    /// Fault plane: kill counts backing the retry backoff.
    pub retry_attempt: BTreeMap<u64, u32>,
    /// Disaggregation: requests between prefill completion and decode
    /// admission (with TTFT and prefill region).
    pub pending_handoffs: BTreeMap<u64, (Request, Time, Region)>,
    /// Disaggregation: TTFTs of requests in flight on decode instances.
    pub inflight_decode: BTreeMap<u64, Time>,
    /// Fault plane: incidents still awaiting capacity recovery.
    pub recovery_watch: Vec<RecoveryWatch>,
    /// Guardrail-controller state (residual window, last-good plan,
    /// cascade rung).
    pub guardrail: GuardrailState,
}

impl Simulation {
    /// Build a simulation: fleet + initial allocation, telemetry with a
    /// week of warm-up history, forecaster, and the initial periodic
    /// events.  The clock starts at `t = 0` with nothing in flight.
    pub fn new(cfg: SimConfig) -> Self {
        let models = cfg.trace.models.clone();
        let perf = PerfTable::for_fleet(&cfg.fleet.gpus(), &models);
        let pools = cfg.strategy.initial_pools(cfg.initial_instances);
        let mut cluster =
            Cluster::new_fleet(&models, perf, cfg.scaling.clone(), &pools, cfg.vm_budget, &cfg.fleet);
        // Partition the initial rosters into prefill/decode pools (a
        // no-op that only copies the params when disaggregation is off).
        cluster.set_disagg(cfg.disagg.clone());

        // Telemetry with one week of warm-up history from the generator's
        // expected rates (the "previous week" the forecaster trains on).
        let mut telemetry = Telemetry::new(&models, 900.0);
        let gen = TraceGenerator::new(cfg.trace.clone());
        let warm_buckets = 672; // 7 days × 96
        let mut warm = BTreeMap::new();
        for &m in &models {
            for r in Region::ALL {
                let series: Vec<f64> = (0..warm_buckets)
                    .map(|b| {
                        // Mirror the week before t=0 (same weekday phase).
                        let t = (b as f64 + 0.5) * 900.0 - warm_buckets as f64 * 900.0;
                        let t_wrapped = t.rem_euclid(7.0 * 86_400.0);
                        let mut tps = 0.0;
                        for tier in [Tier::IwF, Tier::IwN] {
                            tps += gen.rate(m, r, tier, t_wrapped)
                                * mean_input_tokens(m, tier);
                        }
                        tps
                    })
                    .collect();
                warm.insert((m, r), series);
            }
        }
        telemetry.warmup(&warm);

        let forecaster: Box<dyn Forecaster> = if cfg.pjrt_forecaster {
            Box::new(
                crate::forecast::PjrtForecaster::load(&cfg.artifacts_dir)
                    .expect("load forecast artifact (run `make artifacts`)"),
            )
        } else {
            Box::new(NativeArForecaster::new(96, 8, 4))
        };

        let end_time = cfg.trace.days * 86_400.0;
        let autoscaler = Autoscaler::new(cfg.strategy, cfg.scaling.clone());
        let mut sim = Simulation {
            now: 0.0,
            cluster,
            metrics: Metrics::new(cfg.metrics),
            telemetry,
            qm: QueueManager::new(),
            events: EventQueue::new(),
            autoscaler,
            forecaster,
            solvers: SolverStates::new(),
            solvers_decode: SolverStates::new(),
            end_time,
            epoch_start: 0.0,
            tick_count: 0,
            epoch_counts: Vec::new(),
            epoch_counts_decode: Vec::new(),
            pending_retries: BTreeMap::new(),
            retry_attempt: BTreeMap::new(),
            pending_handoffs: BTreeMap::new(),
            inflight_decode: BTreeMap::new(),
            recovery_watch: Vec::new(),
            guardrail: GuardrailState::new(),
            cfg,
        };
        // Seed ledgers with the initial allocation.
        for &m in &models {
            for r in Region::ALL {
                let mut ctx = sim.ctx();
                ctx.record_ledgers(m, r);
            }
        }
        // Periodic events.
        sim.events.push(SCALE_TICK, Event::ScaleTick);
        sim.events.push(MINUTE, Event::QmTick);
        if sim.cfg.strategy.uses_forecast() {
            sim.events.push(0.0, Event::ControlEpoch);
        }
        // Fault schedule (an empty plan pushes nothing, leaving the
        // heap's sequence counter — and thus every pop order — intact).
        sim.cfg.faults.compile(&mut sim.events, end_time);
        sim
    }

    fn ctx(&mut self) -> ScaleCtx<'_> {
        // Control-fault actuation sampling: the empty plan yields exactly
        // `false` / `0.0`, and every consumer branches on those values
        // (no identity arithmetic), so fault-free runs stay bit-identical.
        let act_drop = self.cfg.control_faults.actuation_drop_at(self.now);
        let act_extra_lead = self.cfg.control_faults.actuation_extra_lead_at(self.now);
        ScaleCtx {
            now: self.now,
            cluster: &mut self.cluster,
            metrics: &mut self.metrics,
            events: &mut self.events,
            reroutes: Vec::new(),
            act_drop,
            act_extra_lead,
        }
    }

    /// Run the full trace plus a drain phase for in-flight work.
    pub fn run(&mut self) {
        if let Some(path) = self.cfg.replay_trace.clone() {
            let reqs = crate::trace::io::read_csv(&path)
                .expect("read replay trace (CSV with header)");
            self.run_stream(reqs.into_iter());
        } else if let Some(buf) = self.cfg.shared_trace.clone() {
            // Borrowed pre-materialized buffer: `Request` is `Copy`, so
            // replaying N strategies from one shared buffer allocates
            // nothing per run.
            self.run_stream(buf.iter().copied());
        } else {
            let gen = TraceGenerator::new(self.cfg.trace.clone());
            // Borrow scope: the generator must outlive the stream.
            let stream = gen.stream();
            self.run_stream(stream);
        }
    }

    fn run_stream(&mut self, stream: impl Iterator<Item = Request>) {
        self.run_chunk(stream, None);
        self.finish();
    }

    /// Drive the event loop over one arrival slice.
    ///
    /// `next_after` is the arrival time of the first request *after* this
    /// chunk, or `None` if this is the final (or only) chunk.  Events
    /// strictly before `next_after` are processed before returning, so
    /// consecutive calls pop arrivals and events in exactly the order the
    /// single-pass loop would — the merge decision `ta <= te` (arrival
    /// wins ties) only ever compares the globally-next arrival against
    /// the event heap, whichever chunk that arrival lives in.
    ///
    /// With `next_after = None` the loop also runs the early-termination
    /// check (trace exhausted, cluster idle, queue manager empty); with a
    /// successor chunk pending that check must not fire, since "idle"
    /// mid-trace is just a lull.  Call [`Simulation::finish`] after the
    /// last chunk to drain in-flight work.
    pub fn run_chunk(&mut self, chunk: impl Iterator<Item = Request>, next_after: Option<Time>) {
        let mut chunk = chunk.peekable();
        loop {
            let in_chunk = chunk.peek().is_some();
            let next_arrival = chunk.peek().map(|r| r.arrival).or(next_after);
            let next_event = self.events.peek_time();
            match (next_arrival, next_event) {
                (Some(ta), Some(te)) if ta <= te => {
                    // The next arrival wins the merge; if it belongs to
                    // the successor chunk, this chunk's work is done.
                    if !in_chunk {
                        return;
                    }
                    let req = chunk.next().unwrap();
                    self.now = ta;
                    self.handle_arrival(req);
                }
                (Some(ta), None) => {
                    if !in_chunk {
                        return;
                    }
                    let req = chunk.next().unwrap();
                    self.now = ta;
                    self.handle_arrival(req);
                }
                (_, Some(_)) => {
                    let (t, ev) = self.events.pop().unwrap();
                    self.now = t;
                    // Stop periodic events after the drain horizon.
                    if t > self.end_time + 4.0 * HOUR {
                        break;
                    }
                    self.handle_event(ev);
                }
                (None, None) => break,
            }
            // Termination: trace done and only periodic events remain.
            // Both checks are O(1) counters — this runs every iteration.
            // Gated on `next_after`: with more chunks coming this is a
            // mid-trace lull, not the end.
            if next_after.is_none()
                && chunk.peek().is_none()
                && self.cluster.is_all_idle()
                && self.qm.total_depth() == 0
                && self.pending_retries.is_empty()
                && self.pending_handoffs.is_empty()
            {
                break;
            }
        }
    }

    /// Drain phase after the last chunk: flush NIW stragglers out of the
    /// queue manager, then run remaining events until everything is idle
    /// (bounded by `end_time + 8 h`).  [`Simulation::run`] calls this
    /// automatically; chunked execution calls it once after the final
    /// [`Simulation::run_chunk`].
    pub fn finish(&mut self) {
        // Flush any NIW stragglers so nothing is silently lost.
        let leftovers = self.qm.drain_all();
        for req in leftovers {
            self.route_interactive_like(req);
        }
        while let Some((t, ev)) = self.events.pop() {
            self.now = t;
            if t > self.end_time + 8.0 * HOUR {
                break;
            }
            self.handle_event(ev);
            if self.cluster.is_all_idle()
                && self.qm.total_depth() == 0
                && self.pending_retries.is_empty()
                && self.pending_handoffs.is_empty()
            {
                break;
            }
        }
        // Disaggregation backstop: handoffs that never found a decode
        // instance before the drain horizon are counted as dropped (once
        // each), keeping request conservation exact even under a total
        // decode blackout.
        if !self.pending_handoffs.is_empty() {
            let n = self.pending_handoffs.len() as u64;
            self.metrics.handoff_drops += n;
            self.metrics.dropped += n;
            self.pending_handoffs.clear();
        }
    }

    /// Detach the complete mutable state as a [`SimHandoff`], consuming
    /// the simulation.  Pair with [`Simulation::resume`].
    pub fn suspend(self) -> (SimConfig, SimHandoff) {
        let Simulation {
            now,
            cfg,
            cluster,
            metrics,
            telemetry,
            qm,
            events,
            autoscaler,
            forecaster,
            solvers,
            solvers_decode,
            end_time: _,
            epoch_start,
            tick_count,
            epoch_counts: _,
            epoch_counts_decode: _,
            pending_retries,
            retry_attempt,
            pending_handoffs,
            inflight_decode,
            recovery_watch,
            guardrail,
        } = self;
        (
            cfg,
            SimHandoff {
                now,
                cluster,
                metrics,
                telemetry,
                qm,
                events,
                autoscaler,
                forecaster,
                solvers,
                solvers_decode,
                epoch_start,
                tick_count,
                pending_retries,
                retry_attempt,
                pending_handoffs,
                inflight_decode,
                recovery_watch,
                guardrail,
            },
        )
    }

    /// Re-attach a [`SimHandoff`] to its config and continue.  Unlike
    /// [`Simulation::new`] this performs *no* initialization — no ledger
    /// seeding, no initial periodic events, no telemetry warm-up — the
    /// handoff already carries all of that, mid-flight.
    pub fn resume(cfg: SimConfig, h: SimHandoff) -> Simulation {
        let end_time = cfg.trace.days * 86_400.0;
        Simulation {
            now: h.now,
            cluster: h.cluster,
            metrics: h.metrics,
            telemetry: h.telemetry,
            qm: h.qm,
            events: h.events,
            autoscaler: h.autoscaler,
            forecaster: h.forecaster,
            solvers: h.solvers,
            solvers_decode: h.solvers_decode,
            end_time,
            epoch_start: h.epoch_start,
            tick_count: h.tick_count,
            epoch_counts: Vec::new(),
            epoch_counts_decode: Vec::new(),
            pending_retries: h.pending_retries,
            retry_attempt: h.retry_attempt,
            pending_handoffs: h.pending_handoffs,
            inflight_decode: h.inflight_decode,
            recovery_watch: h.recovery_watch,
            guardrail: h.guardrail,
            cfg,
        }
    }

    // ------------------------------------------------------------------
    // Arrivals and routing
    // ------------------------------------------------------------------

    fn handle_arrival(&mut self, req: Request) {
        self.telemetry.record(
            self.now,
            req.model,
            req.origin,
            req.input_tokens,
            req.tier.is_interactive(),
        );
        // Reactive per-request scaling check (§4).
        let (m, o, tier) = (req.model, req.origin, req.tier);
        let mut ctx = ScaleCtx {
            now: self.now,
            cluster: &mut self.cluster,
            metrics: &mut self.metrics,
            events: &mut self.events,
            reroutes: Vec::new(),
            act_drop: self.cfg.control_faults.actuation_drop_at(self.now),
            act_extra_lead: self.cfg.control_faults.actuation_extra_lead_at(self.now),
        };
        self.autoscaler.on_request(&mut ctx, m, o, tier);
        let rr = std::mem::take(&mut ctx.reroutes);
        for r in rr {
            self.route_interactive_like(r);
        }

        if !req.tier.is_interactive() && self.cfg.strategy.uses_queue_manager() {
            self.qm.enqueue(req);
            return;
        }
        self.route_interactive_like(req);
    }

    /// Route a request through region selection + JSQ (IW path; also used
    /// for NIW under Siloed/Chiron and for aged/released NIW).  On
    /// multi-SKU fleets the SKU-aware variants apply the per-request
    /// affinity policy; homogeneous fleets short-circuit to the blind
    /// path inside the router, so paper experiments are unchanged.
    fn route_interactive_like(&mut self, req: Request) {
        let region = router::route_region_sku_aware(
            &self.cluster,
            &self.cfg.routing,
            req.model,
            req.origin,
            req.total_tokens(),
        );
        self.dispatch_to_region(req, region);
    }

    fn dispatch_to_region(&mut self, req: Request, region: Region) {
        // Disaggregated fleets admit through the prefill-queue JSQ —
        // arrivals must land on prefill instances, which hand their KV
        // off to a decode instance at prefill completion.
        let inst = if self.cfg.disagg.enabled {
            router::route_instance_prefill(&self.cluster, req.model, region, req.tier)
        } else {
            router::route_instance_sku_aware(
                &self.cluster,
                &self.cfg.routing,
                req.model,
                region,
                req.tier,
                req.total_tokens(),
            )
        };
        match inst {
            Some(id) => {
                // Cross-region latency is recomputed at completion from
                // the serving instance's region — no per-request side
                // table to maintain on this path.
                self.cluster.push_waiting(id, req);
                self.kick_instance(id);
            }
            None => {
                self.metrics.dropped += 1;
            }
        }
    }

    // ------------------------------------------------------------------
    // Instance execution
    // ------------------------------------------------------------------

    /// Start a chunk on an idle instance (no-op if busy/not serving).
    fn kick_instance(&mut self, id: InstanceId) {
        let inst = &self.cluster.instances[id];
        if inst.chunk_scheduled || !matches!(inst.state, InstState::Active | InstState::Draining) {
            return;
        }
        self.start_chunk(id);
    }

    fn start_chunk(&mut self, id: InstanceId) {
        let now = self.now;
        // Ordering, admission and chunk planning are fused into one
        // aggregate-coherent cluster call (no perf-profile clone).
        let plan = match self.cluster.plan_next_chunk(id, now, &self.cfg.sched_policy) {
            Some(p) => p,
            None => return, // idle
        };
        // Record TTFT/E2E outcomes with exact in-chunk timestamps,
        // reading the sequences in place (no per-completion clone).
        // Cross-region latency is derived from where the request was
        // actually served, replacing the old per-request side table.
        //
        // With a fault plan active, recording moves to the chunk *end*
        // (`record_completed_outcomes`): a VM can die mid-chunk, and a
        // completion planned for after the crash instant must count as
        // killed, not completed.  The empty-plan path records here,
        // eagerly — byte-identical to the fault-plane-free engine.
        let served_region = self.cluster.instances[id].region;
        if self.cfg.faults.is_empty() {
            match self.cluster.instances[id].phase {
                // Prefill pool: a "completion" is a finished prefill —
                // start the KV transfer and park the request for decode
                // admission instead of recording an outcome.
                Phase::Prefill => {
                    for &(idx, t_done) in &plan.completions {
                        let (req, prefill_done) = {
                            let seq = &self.cluster.instances[id].batch[idx];
                            (seq.req, seq.prefill_done)
                        };
                        self.record_handoff(id, req, prefill_done, t_done, 0.0);
                    }
                }
                // Decode pool: the TTFT was stamped at the prefill
                // handoff; only the E2E is measured here.
                Phase::Decode => {
                    for &(idx, t_done) in &plan.completions {
                        let (req, prefill_done) = {
                            let seq = &self.cluster.instances[id].batch[idx];
                            (seq.req, seq.prefill_done)
                        };
                        self.record_decode_completion(req, prefill_done, t_done, served_region, 0.0);
                    }
                }
                Phase::Unified => {
                    for &(idx, t_done) in &plan.completions {
                        let seq = &self.cluster.instances[id].batch[idx];
                        let extra =
                            router::routing_latency(&self.cfg.routing, seq.req.origin, served_region);
                        let ttft = seq.prefill_done - seq.req.arrival + extra;
                        let e2e = t_done - seq.req.arrival + extra;
                        self.metrics.record_outcome(&seq.req, served_region, ttft, e2e);
                    }
                }
            }
        }
        self.events.push(now + plan.duration, Event::ChunkDone { instance: id });
    }

    /// Record a finished prefill on a disaggregated fleet: stamp the
    /// TTFT (first token emerges at prefill completion), charge the
    /// KV-cache migration at the source SKU's transfer rate, and
    /// schedule the decode admission for when the transfer lands.
    fn record_handoff(
        &mut self,
        id: InstanceId,
        req: Request,
        prefill_done: Time,
        t_done: Time,
        penalty: f64,
    ) {
        let (region, gpu, model) = {
            let inst = &self.cluster.instances[id];
            (inst.region, inst.gpu, inst.model)
        };
        let extra = router::routing_latency(&self.cfg.routing, req.origin, region) + penalty;
        let ttft = prefill_done - req.arrival + extra;
        let transfer =
            self.cluster.perf.profile(model, gpu).kv_transfer_time(req.input_tokens as u64);
        self.metrics.handoffs += 1;
        self.metrics.kv_transfer_secs += transfer;
        self.pending_handoffs.insert(req.id, (req, ttft, region));
        self.events
            .push((t_done + transfer).max(self.now), Event::HandoffDue { id: req.id });
    }

    /// Record a finished decode on a disaggregated fleet: the TTFT
    /// travels through `inflight_decode` from the handoff; a request
    /// that reached a decode instance without one (the degenerate
    /// no-prefill-roster fallback) falls back to its in-batch
    /// `prefill_done` stamp, which for a decode-phase instance is its
    /// admission time.
    fn record_decode_completion(
        &mut self,
        req: Request,
        prefill_done: Time,
        t_done: Time,
        served_region: Region,
        penalty: f64,
    ) {
        let extra = router::routing_latency(&self.cfg.routing, req.origin, served_region) + penalty;
        let e2e = t_done - req.arrival + extra;
        let ttft = self
            .inflight_decode
            .remove(&req.id)
            .unwrap_or(prefill_done - req.arrival + extra);
        self.metrics.record_outcome(&req, served_region, ttft, e2e);
        self.retry_attempt.remove(&req.id);
    }

    /// KV transfer landed: admit the request to a decode instance.  No
    /// live decode instance anywhere ⇒ re-arm after a backoff (capacity
    /// may return after an outage); `finish` counts anything still
    /// parked at the drain horizon as dropped.
    fn on_handoff_due(&mut self, id: u64) {
        let Some(&(req, ttft, from_region)) = self.pending_handoffs.get(&id) else {
            return; // already resolved
        };
        match router::route_instance_decode(
            &self.cluster,
            &self.cfg.routing,
            req.model,
            from_region,
            req.tier,
            req.input_tokens as u64,
        ) {
            Some(inst) => {
                self.pending_handoffs.remove(&id);
                self.metrics.handoff_admissions += 1;
                self.inflight_decode.insert(id, ttft);
                self.cluster.push_waiting(inst, req);
                self.kick_instance(inst);
            }
            None => {
                self.events.push(self.now + MINUTE, Event::HandoffDue { id });
            }
        }
    }

    /// Fault-plan outcome recording at a chunk boundary: every batch
    /// sequence with a planned completion genuinely finished (the chunk
    /// ran to its end — crashes sweep their instance's batch before this
    /// can fire), so record it now, charge any degradation penalty of
    /// the serving region, and drop its retry bookkeeping.
    fn record_completed_outcomes(&mut self, id: InstanceId) {
        let served_region = self.cluster.instances[id].region;
        let penalty = self.cluster.latency_penalty(served_region);
        let phase = self.cluster.instances[id].phase;
        for idx in 0..self.cluster.instances[id].batch.len() {
            let (req, prefill_done, completed) = {
                let seq = &self.cluster.instances[id].batch[idx];
                (seq.req, seq.prefill_done, seq.completed_at)
            };
            let Some(t_done) = completed else { continue };
            match phase {
                Phase::Prefill => self.record_handoff(id, req, prefill_done, t_done, penalty),
                Phase::Decode => {
                    self.record_decode_completion(req, prefill_done, t_done, served_region, penalty)
                }
                Phase::Unified => {
                    let extra =
                        router::routing_latency(&self.cfg.routing, req.origin, served_region)
                            + penalty;
                    let ttft = prefill_done - req.arrival + extra;
                    let e2e = t_done - req.arrival + extra;
                    self.metrics.record_outcome(&req, served_region, ttft, e2e);
                    self.retry_attempt.remove(&req.id);
                }
            }
        }
    }

    fn on_chunk_done(&mut self, id: InstanceId) {
        if self.cluster.instances[id].state == InstState::Dead {
            return; // stale event: the VM died mid-chunk
        }
        if !self.cfg.faults.is_empty() {
            self.record_completed_outcomes(id);
        }
        let (is_draining, batch_empty) = self.cluster.mutate(id, |inst| {
            inst.chunk_scheduled = false;
            inst.retire_completed();
            (inst.state == InstState::Draining, inst.batch.is_empty())
        });
        // Draining instance with an empty batch converts to spot; its
        // waiting queue (if any) is re-routed.
        if is_draining && batch_empty {
            let stragglers: Vec<Request> = self.cluster.take_waiting(id);
            let (model, region) = {
                let i = &self.cluster.instances[id];
                (i.model, i.region)
            };
            self.cluster.finish_drain(id);
            let mut ctx = ScaleCtx {
                now: self.now,
                cluster: &mut self.cluster,
                metrics: &mut self.metrics,
                events: &mut self.events,
                reroutes: Vec::new(),
                // Ledger-only context: no actuation flows through it.
                act_drop: false,
                act_extra_lead: 0.0,
            };
            ctx.record_ledgers(model, region);
            for r in stragglers {
                self.route_interactive_like(r);
            }
            return;
        }
        self.start_chunk(id);
    }

    fn on_provision_done(&mut self, id: InstanceId) {
        self.cluster.mutate(id, |inst| {
            if let InstState::Provisioning { .. } = inst.state {
                inst.state = InstState::Active;
            }
        });
        self.kick_instance(id);
        // Replacement capacity landing after an outage may close an
        // open incident (time-to-recover).
        if !self.recovery_watch.is_empty() {
            let region = self.cluster.instances[id].region;
            self.check_recovery(region);
        }
    }

    // ------------------------------------------------------------------
    // Fault plane
    // ------------------------------------------------------------------

    /// Kill one roster VM (outage or crash hazard): finished-before-the-
    /// crash sequences still record their outcomes; everything else is
    /// counted killed and re-enters through the retry path.
    fn kill_instance(&mut self, id: InstanceId) {
        let (model, region) = {
            let inst = &self.cluster.instances[id];
            (inst.model, inst.region)
        };
        let penalty = self.cluster.latency_penalty(region);
        let work = self.cluster.crash_instance(id, self.now);
        // The de-rostered instance keeps its phase tag precisely so
        // finished-before-the-crash work can be classified here:
        // prefill-pool completions become handoffs, decode-pool
        // completions consume their in-flight TTFT.
        let phase = self.cluster.instances[id].phase;
        for seq in &work.finished {
            let t_done = seq.completed_at.expect("finished seq has a completion");
            match phase {
                Phase::Prefill => {
                    self.record_handoff(id, seq.req, seq.prefill_done, t_done, penalty)
                }
                Phase::Decode => {
                    self.record_decode_completion(seq.req, seq.prefill_done, t_done, region, penalty)
                }
                Phase::Unified => {
                    let extra =
                        router::routing_latency(&self.cfg.routing, seq.req.origin, region) + penalty;
                    let ttft = seq.prefill_done - seq.req.arrival + extra;
                    let e2e = t_done - seq.req.arrival + extra;
                    self.metrics.record_outcome(&seq.req, region, ttft, e2e);
                    self.retry_attempt.remove(&seq.req.id);
                }
            }
        }
        for req in work.killed {
            self.metrics.failures.record_killed(req.model, req.tier, req.origin);
            self.on_request_killed(req);
        }
        let mut ctx = self.ctx();
        ctx.record_ledgers(model, region);
    }

    /// A killed request either schedules a retry (capped exponential
    /// backoff, original arrival time kept for SLA accounting) or — past
    /// `max_attempts` kills — is permanently lost.
    fn on_request_killed(&mut self, req: Request) {
        // A killed decode-phase request redoes its prefill on retry, so
        // its stamped TTFT is stale — drop it (no-op on unified runs).
        self.inflight_decode.remove(&req.id);
        let attempt = {
            let a = self.retry_attempt.entry(req.id).or_insert(0);
            *a += 1;
            *a
        };
        if attempt > self.cfg.faults.retry.max_attempts {
            self.retry_attempt.remove(&req.id);
            self.metrics.failures.record_lost(req.model, req.tier, req.origin);
            return;
        }
        let delay = self.cfg.faults.retry.backoff(attempt);
        self.pending_retries.insert(req.id, req);
        self.events.push(self.now + delay, Event::RetryDue { id: req.id });
    }

    /// Backoff expired: fail the request over to a live (preferably
    /// clean) region.  No routable region or no instance ⇒ the kill
    /// counter ticks again and the request backs off or is lost.
    fn on_retry_due(&mut self, id: u64) {
        let Some(req) = self.pending_retries.remove(&id) else {
            return; // already resolved (e.g. lost via a later kill)
        };
        let dest = router::route_retry(
            &self.cluster,
            &self.cfg.routing,
            req.model,
            req.origin,
            req.total_tokens(),
        );
        let inst = dest.and_then(|region| {
            if self.cfg.disagg.enabled {
                // Retries redo their prefill: admission goes back through
                // the prefill-queue JSQ, and the decode handoff repeats.
                router::route_instance_prefill(&self.cluster, req.model, region, req.tier)
            } else {
                router::route_instance_sku_aware(
                    &self.cluster,
                    &self.cfg.routing,
                    req.model,
                    region,
                    req.tier,
                    req.total_tokens(),
                )
            }
        });
        match inst {
            Some(id) => {
                self.metrics.failures.retries += 1;
                self.cluster.push_waiting(id, req);
                self.kick_instance(id);
            }
            None => self.on_request_killed(req),
        }
    }

    /// Active + provisioning instances across every model endpoint of a
    /// region (the recovery target and its progress measure).
    fn region_active_count(&self, region: Region) -> usize {
        let mut n = 0;
        for idx in 0..self.cluster.endpoints.len() {
            let (model, r) = self.cluster.endpoints.key_at(idx);
            if r == region {
                n += self.cluster.allocated_count(model, r);
            }
        }
        n
    }

    /// Region goes dark: mask it out of routing/provisioning, kill every
    /// roster VM (all models, provisioning included), reclaim the whole
    /// donated spot pool, and open a recovery watch against the
    /// pre-outage capacity level.
    fn on_outage_start(&mut self, idx: usize) {
        let region = self.cfg.faults.outages[idx].region;
        let target = self.region_active_count(region);
        let incident = self.metrics.failures.open_incident("region-outage", region, self.now);
        self.recovery_watch.push(RecoveryWatch { outage: idx, region, target, incident });
        self.cluster.set_region_dark(region, true);
        let mut victims: Vec<InstanceId> = Vec::new();
        for ep_idx in 0..self.cluster.endpoints.len() {
            let (model, r) = self.cluster.endpoints.key_at(ep_idx);
            if r == region {
                victims.extend(&self.cluster.endpoints[&(model, r)].instances);
            }
        }
        for id in victims {
            self.kill_instance(id);
        }
        let pool = self.cluster.spot_count(region);
        if pool > 0 {
            self.cluster.preempt_spot(region, pool);
        }
        // Spot ledgers for the region change wholesale; re-record every
        // endpoint once (kill_instance covered non-empty rosters, this
        // covers endpoints that only had donated VMs in the pool).
        self.record_region_ledgers(region);
    }

    /// Outage window closes: lift the mask and re-seed each of the
    /// region's endpoints back to the `min_instances` floor at realistic
    /// provisioning lead time — demand-driven scaling grows the rest,
    /// and the recovery watch stamps time-to-recover when the pre-outage
    /// level is back.
    fn on_outage_end(&mut self, idx: usize) {
        let region = self.cfg.faults.outages[idx].region;
        self.cluster.set_region_dark(region, false);
        if let Some(w) = self.recovery_watch.iter().find(|w| w.outage == idx) {
            self.metrics.failures.set_fault_end(w.incident, self.now);
        }
        let floor = self.cfg.scaling.min_instances;
        let pools = self.cfg.strategy.initial_pools(1);
        let seed_pool = pools[0].0;
        for ep_idx in 0..self.cluster.endpoints.len() {
            let (model, r) = self.cluster.endpoints.key_at(ep_idx);
            if r != region {
                continue;
            }
            while self.cluster.allocated_count(model, region) < floor {
                if !self.provision_replacement(model, region, seed_pool) {
                    break; // no budget / no SKU left
                }
            }
        }
        self.check_recovery(region);
    }

    /// Provision one replacement VM (cheapest SKU with capacity),
    /// mirroring the autoscaler's commit: ProvisionDone scheduled at the
    /// realistic lead time, ledgers re-recorded.
    fn provision_replacement(
        &mut self,
        model: ModelKind,
        region: Region,
        pool: crate::sim::cluster::PoolTag,
    ) -> bool {
        let order = self.cluster.gpus_cost_asc.clone();
        for gpu in order {
            let got = self.cluster.scale_out(model, region, pool, gpu, self.now, &mut self.metrics);
            if let Some((id, ready, prev)) = got {
                self.events.push(ready, Event::ProvisionDone { instance: id });
                let mut ctx = self.ctx();
                ctx.record_ledgers(model, region);
                if prev != model {
                    let mut ctx = self.ctx();
                    ctx.record_ledgers(prev, region);
                }
                return true;
            }
        }
        false
    }

    /// Spot-market preemption shock: the external market claims `frac`
    /// of every region's donated pool (rounded up), for good.
    fn on_spot_shock(&mut self, idx: usize) {
        let frac = self.cfg.faults.spot_shocks[idx].frac;
        for region in Region::ALL {
            let pool = self.cluster.spot_count(region);
            let n = (pool as f64 * frac).ceil() as usize;
            if n == 0 {
                continue;
            }
            let taken = self.cluster.preempt_spot(region, n);
            if taken > 0 {
                let i = self.metrics.failures.open_incident("spot-shock", region, self.now);
                self.metrics.failures.set_fault_end(i, self.now);
                self.record_region_ledgers(region);
            }
        }
    }

    /// Counter-seeded VM-crash hazard tick `k` (at `k × crash_check_secs`):
    /// each roster VM flips an independent coin from a tick-pure RNG —
    /// no RNG state rides the handoff, so chunked == sequential.  Victims
    /// get same-endpoint replacements immediately (the health checker's
    /// replace-on-failure), at full provisioning lead time.
    fn on_crash_tick(&mut self, k: u64) {
        let p = self.cfg.faults.crash_prob_per_tick();
        let mut rng = FaultPlan::crash_rng(self.cfg.trace.seed, k);
        let mut victims: Vec<InstanceId> = Vec::new();
        // Dense endpoint order + roster order: a deterministic walk.
        for ep_idx in 0..self.cluster.endpoints.len() {
            let key = self.cluster.endpoints.key_at(ep_idx);
            for &iid in &self.cluster.endpoints[&key].instances {
                if rng.f64() < p {
                    victims.push(iid);
                }
            }
        }
        for id in victims {
            let (model, region) = {
                let inst = &self.cluster.instances[id];
                (inst.model, inst.region)
            };
            let pool = self.cluster.instances[id].pool;
            self.kill_instance(id);
            if self.cluster.region_available(region) {
                self.provision_replacement(model, region, pool);
            }
        }
        if self.now < self.end_time {
            self.events
                .push(self.now + self.cfg.faults.crash_check_secs, Event::FaultCrashTick { k: k + 1 });
        }
    }

    /// Close any recovery watch whose region is live again at (or above)
    /// its pre-incident capacity.
    fn check_recovery(&mut self, region: Region) {
        let mut i = 0;
        while i < self.recovery_watch.len() {
            let w = &self.recovery_watch[i];
            if w.region == region
                && self.cluster.region_available(region)
                && self.region_active_count(region) >= w.target
            {
                self.metrics.failures.set_recovered(w.incident, self.now);
                self.recovery_watch.remove(i);
            } else {
                i += 1;
            }
        }
    }

    /// Re-record every ledger touching one region (bulk fault events —
    /// outage sweeps, spot shocks — change many at once).
    fn record_region_ledgers(&mut self, region: Region) {
        for ep_idx in 0..self.cluster.endpoints.len() {
            let (model, r) = self.cluster.endpoints.key_at(ep_idx);
            if r == region {
                let mut ctx = self.ctx();
                ctx.record_ledgers(model, region);
            }
        }
    }

    // ------------------------------------------------------------------
    // Periodic control
    // ------------------------------------------------------------------

    fn handle_event(&mut self, ev: Event) {
        match ev {
            Event::ChunkDone { instance } => self.on_chunk_done(instance),
            Event::ProvisionDone { instance } => self.on_provision_done(instance),
            Event::ScaleTick => self.on_scale_tick(),
            Event::QmTick => self.on_qm_tick(),
            Event::ControlEpoch => self.on_control_epoch(),
            Event::FaultOutageStart { idx } => self.on_outage_start(idx),
            Event::FaultOutageEnd { idx } => self.on_outage_end(idx),
            Event::FaultDegradeStart { idx } => {
                let d = &self.cfg.faults.degradations[idx];
                let (region, extra) = (d.region, d.extra);
                self.cluster.set_region_degraded(region, extra);
            }
            Event::FaultDegradeEnd { idx } => {
                let region = self.cfg.faults.degradations[idx].region;
                self.cluster.clear_region_degraded(region);
            }
            Event::FaultSpotShock { idx } => self.on_spot_shock(idx),
            Event::FaultCrashTick { k } => self.on_crash_tick(k),
            Event::RetryDue { id } => self.on_retry_due(id),
            Event::HandoffDue { id } => self.on_handoff_due(id),
        }
    }

    fn on_scale_tick(&mut self) {
        self.tick_count += 1;
        // LT/Chiron scaling progression.  Under a telemetry freeze every
        // reader — the gap check included — sees the world as of the
        // moment the feed died (the telemetry store keeps full bucketized
        // history, so reading at a past instant needs no extra state).
        // With no freeze `t_obs == now` and the read is byte-identical.
        let t_obs =
            self.cfg.control_faults.telemetry_frozen_since(self.now).unwrap_or(self.now);
        let observed = self.telemetry.recent_tps_all(t_obs);
        let elapsed = self.now - self.epoch_start;
        let mut ctx = ScaleCtx {
            now: self.now,
            cluster: &mut self.cluster,
            metrics: &mut self.metrics,
            events: &mut self.events,
            reroutes: Vec::new(),
            act_drop: self.cfg.control_faults.actuation_drop_at(self.now),
            act_extra_lead: self.cfg.control_faults.actuation_extra_lead_at(self.now),
        };
        self.autoscaler.on_tick(&mut ctx, &observed, elapsed);
        // Guardrail cascade, bottom rung: with the control plane degraded
        // past the held-plan budget, proportional control on *live
        // cluster* utilization (not telemetry — the cluster's own
        // aggregates cannot go stale) backstops the stale targets.
        if self.cfg.guardrails.enabled
            && self.cfg.strategy.uses_forecast()
            && self.guardrail.mode == GuardrailMode::Reactive
        {
            self.autoscaler.guardrail_reactive_tick(&mut ctx);
        }
        // Backstop: convert Draining instances that can no longer make
        // progress (empty batch, no chunk in flight) — see
        // `ScaleCtx::sweep_stalled_drains`.  A no-op on healthy runs.
        ctx.sweep_stalled_drains();
        let rr = std::mem::take(&mut ctx.reroutes);
        for r in rr {
            self.route_interactive_like(r);
        }

        // NIW release signals (§6.2) for queue-manager strategies.  Each
        // endpoint keeps signalling while it has headroom, so the queue
        // drains at the endpoints' actual spare capacity; the
        // waiting-aware utilization makes the loop self-limiting.
        //
        // Graceful degradation (fault plane): while any region is dark,
        // NIW releases are deferred entirely — the surviving capacity
        // serves interactive traffic first, and batch work waits (or is
        // shed by the QmTick sweep) rather than compete for it.
        if self.cfg.strategy.uses_queue_manager()
            && self.qm.total_depth() > 0
            && !self.cluster.any_region_dark()
        {
            // Index-based endpoint walk: no per-tick key Vec.
            for idx in 0..self.cluster.endpoints.len() {
                let (model, region) = self.cluster.endpoints.key_at(idx);
                loop {
                    if self.qm.depth(model) == 0 {
                        break;
                    }
                    let util = self.cluster.effective_util_with_waiting(model, region);
                    let released =
                        self.qm
                            .on_capacity_signal(&self.cfg.scaling, model, region, util);
                    if released.is_empty() {
                        break;
                    }
                    for (req, region) in released {
                        // Released NIW goes through the same SKU-aware
                        // cascade as live arrivals: long-context work may
                        // spill to a region whose top-HBM SKU has
                        // headroom instead of being pinned to the
                        // signalling region.  Homogeneous fleets
                        // short-circuit to the signalling region.
                        let dest = router::route_released_niw(
                            &self.cluster,
                            &self.cfg.routing,
                            req.model,
                            region,
                            req.total_tokens(),
                        );
                        self.dispatch_to_region(req, dest);
                    }
                }
            }
        }

        // Utilization samples for Fig 8b/12b/14a (every 15 min), folded
        // into the streaming per-bin mean/max accumulator.
        if self.tick_count % UTIL_SAMPLE_EVERY == 0 {
            for idx in 0..self.cluster.endpoints.len() {
                let (model, region) = self.cluster.endpoints.key_at(idx);
                let util = self.cluster.effective_util(model, region);
                self.metrics.record_util(self.now, model, region, util);
            }
        }
        if self.now < self.end_time + 4.0 * HOUR {
            self.events.push(self.now + SCALE_TICK, Event::ScaleTick);
        }
    }

    fn on_qm_tick(&mut self) {
        let aged = self.qm.pop_aged(&self.cfg.scaling, self.now);
        for req in aged {
            self.route_interactive_like(req);
        }
        // Graceful degradation: under a region outage, shed the NIW
        // backlog beyond what the surviving fleet can plausibly absorb
        // (active instances × batch cap per model).  Interactive traffic
        // is never shed — only NIW work parks in the queue manager.
        if self.cluster.any_region_dark()
            && self.cfg.strategy.uses_queue_manager()
            && self.qm.total_depth() > 0
        {
            self.shed_niw_over_capacity();
        }
        if self.now < self.end_time + 4.0 * HOUR {
            self.events.push(self.now + MINUTE, Event::QmTick);
        }
    }

    /// Shed each model's parked NIW backlog down to the surviving
    /// fleet's absorbable depth (Σ live instances × [`MAX_BATCH`]),
    /// newest-first so the oldest (deadline-nearest) requests keep their
    /// place.  Shed requests are counted exactly once — they never
    /// re-enter any queue or instance.
    fn shed_niw_over_capacity(&mut self) {
        let models = self.cfg.trace.models.clone();
        for model in models {
            let mut live = 0usize;
            for r in Region::ALL {
                if self.cluster.region_available(r) {
                    live += self.cluster.allocated_count(model, r);
                }
            }
            let cap = live * crate::sim::instance::MAX_BATCH;
            let shed = self.qm.shed_over_depth(model, cap);
            for req in shed {
                self.metrics.failures.record_shed(req.model, req.tier, req.origin);
            }
        }
    }

    fn on_control_epoch(&mut self) {
        self.epoch_start = self.now;
        // Per-SKU allocated counts n_{j,k}: a dense, telemetry-key-ordered
        // array read straight off the `EndpointMap` aggregates into a
        // reused buffer, replacing the per-epoch `BTreeMap<_, Vec<usize>>`
        // snapshot.  (The 15 s tick's `recent_tps_all` map is the one
        // remaining recurring control-path allocation.)
        let plan = if self.cfg.disagg.enabled {
            // Disaggregated control epoch: per-phase counts feed two
            // capacity solves under one shared budget (TTFT gates the
            // prefill column, ITL the decode column), and the refined
            // pool split steers how future scale-outs are partitioned.
            self.epoch_counts.clear();
            self.epoch_counts_decode.clear();
            for &(m, r) in self.telemetry.keys() {
                self.epoch_counts.push(self.cluster.phase_alloc_by_gpu(m, r, Phase::Prefill));
                self.epoch_counts_decode.push(self.cluster.phase_alloc_by_gpu(m, r, Phase::Decode));
            }
            let (plan, frac) = run_epoch_disagg(
                &self.telemetry,
                self.forecaster.as_mut(),
                &self.cluster.perf,
                &self.cluster.gpus,
                &self.cfg.scaling,
                &self.cfg.disagg,
                &self.epoch_counts,
                &self.epoch_counts_decode,
                &mut self.solvers,
                &mut self.solvers_decode,
                self.now,
            );
            self.cluster.disagg.prefill_fraction = frac;
            plan
        } else {
            self.epoch_counts.clear();
            for &(m, r) in self.telemetry.keys() {
                self.epoch_counts.push(
                    self.cluster
                        .endpoints
                        .get(&(m, r))
                        .map(|ep| ep.alloc_by_gpu)
                        .unwrap_or([0; GpuKind::COUNT]),
                );
            }
            let cf = &self.cfg.control_faults;
            if self.cfg.guardrails.enabled || !cf.is_empty() {
                // Watchdog stamp: what the control-fault plane is doing
                // to this epoch's inputs (all identity when no window is
                // open).  The per-cause counters are engine-level so the
                // *naive* controller's exposure is visible too; degraded
                // time, by contrast, only accrues on the guarded path.
                let mods = ControlEpochMods {
                    forecast_blackout: cf.forecast_blackout_at(self.now),
                    forecast_corruption: cf.forecast_corruption_at(self.now),
                    telemetry_now: cf.telemetry_frozen_since(self.now),
                    solver_fault: cf.solver_fault_at(self.now),
                    theta_deflate: 0.0,
                };
                let g = &mut self.metrics.guardrails;
                if mods.forecast_blackout {
                    g.blackout_epochs += 1;
                }
                if mods.forecast_corruption.is_some() {
                    g.corrupt_epochs += 1;
                }
                if mods.telemetry_now.is_some() {
                    g.stale_epochs += 1;
                }
                if mods.solver_fault {
                    g.solver_fault_epochs += 1;
                }
                if self.cfg.guardrails.enabled {
                    guardrail_epoch(
                        &self.telemetry,
                        self.forecaster.as_mut(),
                        &self.cluster.perf,
                        &self.cluster.gpus,
                        &self.cfg.scaling,
                        &self.cfg.guardrails,
                        &self.epoch_counts,
                        &mut self.solvers,
                        self.now,
                        &mods,
                        &mut self.guardrail,
                        &mut self.metrics.guardrails,
                    )
                } else {
                    run_epoch_modded(
                        &self.telemetry,
                        self.forecaster.as_mut(),
                        &self.cluster.perf,
                        &self.cluster.gpus,
                        &self.cfg.scaling,
                        &self.epoch_counts,
                        &mut self.solvers,
                        self.now,
                        &mods,
                    )
                }
            } else {
                run_epoch(
                    &self.telemetry,
                    self.forecaster.as_mut(),
                    &self.cluster.perf,
                    &self.cluster.gpus,
                    &self.cfg.scaling,
                    &self.epoch_counts,
                    &mut self.solvers,
                    self.now,
                )
            }
        };
        let mut ctx = ScaleCtx {
            now: self.now,
            cluster: &mut self.cluster,
            metrics: &mut self.metrics,
            events: &mut self.events,
            reroutes: Vec::new(),
            act_drop: self.cfg.control_faults.actuation_drop_at(self.now),
            act_extra_lead: self.cfg.control_faults.actuation_extra_lead_at(self.now),
        };
        self.autoscaler.on_epoch(&mut ctx, &plan);
        let rr = std::mem::take(&mut ctx.reroutes);
        for r in rr {
            self.route_interactive_like(r);
        }
        if self.now < self.end_time {
            self.events
                .push(self.now + self.cfg.scaling.control_interval, Event::ControlEpoch);
        }
    }

    /// Total instance-hours per model across regions (Fig 11 metric).
    pub fn instance_hours(&self, model: ModelKind) -> f64 {
        self.metrics.model_instance_hours(model, self.end_time)
    }

    /// End of the arrival window (`trace.days` in seconds); the drain
    /// phase may run up to 8 h past this.
    pub fn end_time(&self) -> Time {
        self.end_time
    }
}

/// Mean input tokens per request for a (model, tier) — mirrors the
/// generator's log-normal parameters (used for telemetry warm-up).
fn mean_input_tokens(model: ModelKind, tier: Tier) -> f64 {
    // Total minus output share: reuse the exact total and approximate the
    // input fraction from the distribution parameters (inputs dominate).
    let total = TraceGenerator::mean_tokens_exact(model, tier);
    0.85 * total
}

/// Convenience: run one simulation for an epoch/strategy and return it.
pub fn run_simulation(cfg: SimConfig) -> Simulation {
    let mut sim = Simulation::new(cfg);
    sim.run();
    sim
}

/// Small helper for tests/examples: a 1-model fast config.
pub fn quick_config(strategy: Strategy, days: f64, scale: f64) -> SimConfig {
    SimConfig {
        trace: TraceConfig {
            days,
            scale,
            epoch: Epoch::Jul2025,
            models: vec![ModelKind::Llama2_70B],
            bursts: false,
            ..Default::default()
        },
        strategy,
        initial_instances: 6,
        ..Default::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_quick(strategy: Strategy) -> Simulation {
        let mut cfg = quick_config(strategy, 0.1, 0.005);
        cfg.scaling.max_instances = 10;
        run_simulation(cfg)
    }

    #[test]
    fn conservation_no_request_lost() {
        let sim = run_quick(Strategy::Reactive);
        let gen = TraceGenerator::new(sim.cfg.trace.clone());
        let total = gen.stream().count();
        assert!(total > 100, "trace too small: {total}");
        assert_eq!(
            sim.metrics.completed as usize + sim.metrics.dropped as usize,
            total,
            "every request must complete or be explicitly dropped"
        );
        assert_eq!(sim.metrics.dropped, 0, "healthy run must not drop");
        // The streaming default keeps no per-request log.
        assert!(sim.metrics.outcomes.is_empty(), "streaming mode must not log outcomes");
    }

    #[test]
    fn latencies_positive_and_ordered() {
        // Exact mode: this invariant needs the raw per-request log.
        let mut cfg = quick_config(Strategy::Reactive, 0.1, 0.005);
        cfg.scaling.max_instances = 10;
        cfg.metrics.mode = crate::metrics::MetricsMode::Exact;
        let sim = run_simulation(cfg);
        assert!(!sim.metrics.outcomes.is_empty());
        for o in &sim.metrics.outcomes {
            assert!(o.ttft > 0.0, "ttft {}", o.ttft);
            assert!(o.e2e >= o.ttft, "e2e {} < ttft {}", o.e2e, o.ttft);
        }
    }

    #[test]
    fn lt_strategies_run_control_epochs() {
        let sim = run_quick(Strategy::LtUa);
        assert!(sim.metrics.completed > 0);
        // Targets were armed at least once.
        let any_target = sim.cluster.endpoints.values().any(|e| e.target.is_some());
        assert!(any_target, "control epoch never armed a target");
    }

    #[test]
    fn qm_used_only_by_unified_strategies() {
        let sim = run_quick(Strategy::Reactive);
        assert!(sim.qm.total_enqueued > 0, "NIW must flow through the QM");
        let sim = run_quick(Strategy::Siloed);
        assert_eq!(sim.qm.total_enqueued, 0, "siloed routes NIW directly");
    }

    #[test]
    fn niw_completes_before_deadline_mostly() {
        let sim = run_quick(Strategy::LtU);
        let niw = sim.metrics.latency_by_tier(Tier::Niw);
        assert!(niw.count > 0);
        assert!(
            niw.sla_violation_rate < 0.05,
            "NIW deadline miss rate: {:.3} over {} requests",
            niw.sla_violation_rate,
            niw.count
        );
    }

    #[test]
    fn instance_hours_accounted() {
        let sim = run_quick(Strategy::Reactive);
        let ih = sim.instance_hours(ModelKind::Llama2_70B);
        // 3 regions × ≤6 instances × 2.4h ≈ ≤43 instance-hours; min 2/region.
        assert!(ih > 1.0 && ih < 50.0, "instance-hours {ih}");
    }

    #[test]
    fn cluster_accounting_stays_coherent() {
        // The O(1) aggregate reads are only as good as the incremental
        // bookkeeping behind them — recount from scratch after a run.
        for strategy in [Strategy::Reactive, Strategy::LtU] {
            let sim = run_quick(strategy);
            assert!(sim.cluster.aggregates_consistent(), "{}", strategy.name());
        }
    }

    #[test]
    fn suspend_resume_roundtrip_is_identity() {
        // A handoff roundtrip before the run starts (and the implicit
        // per-boundary roundtrips in `sim::chunked`) must not perturb
        // anything: the resumed simulation replays bit-identically.
        let mk = || {
            let mut cfg = quick_config(Strategy::LtUa, 0.1, 0.005);
            cfg.scaling.max_instances = 10;
            cfg
        };
        let (cfg, handoff) = Simulation::new(mk()).suspend();
        let mut resumed = Simulation::resume(cfg, handoff);
        resumed.run();
        let reference = run_simulation(mk());
        assert!(resumed.metrics == reference.metrics);
    }

    #[test]
    fn manual_chunk_split_matches_run() {
        // Split the arrival stream by hand at an arbitrary (non-epoch)
        // boundary and drive run_chunk/finish directly; the merge order
        // is invariant to where the stream is cut.
        let mk = || {
            let mut cfg = quick_config(Strategy::Reactive, 0.1, 0.005);
            cfg.scaling.max_instances = 10;
            cfg
        };
        let reference = run_simulation(mk());

        let cfg = mk();
        let reqs: Vec<Request> = TraceGenerator::new(cfg.trace.clone()).stream().collect();
        assert!(reqs.len() > 100);
        let cut = reqs.len() / 3;
        let mut sim = Simulation::new(cfg);
        sim.run_chunk(reqs[..cut].iter().copied(), Some(reqs[cut].arrival));
        let (cfg, handoff) = sim.suspend();
        let mut sim = Simulation::resume(cfg, handoff);
        sim.run_chunk(reqs[cut..].iter().copied(), None);
        sim.finish();
        assert!(sim.metrics == reference.metrics);
    }

    #[test]
    fn empty_fault_plan_gate_is_bit_identical() {
        // The engine's fault paths are gated on `FaultPlan::is_empty`,
        // not on byte-equality with the default: a plan whose retry
        // knobs differ but that schedules nothing must leave every
        // accumulator cell bit-identical to the default run.
        let reference = run_quick(Strategy::LtUa);
        let mut cfg = quick_config(Strategy::LtUa, 0.1, 0.005);
        cfg.scaling.max_instances = 10;
        cfg.faults.retry.max_attempts = 2;
        assert!(cfg.faults.is_empty());
        let sim = run_simulation(cfg);
        assert!(sim.metrics == reference.metrics);
    }

    #[test]
    fn killed_request_keeps_original_arrival_and_backs_off() {
        let mut sim = Simulation::new(quick_config(Strategy::Reactive, 0.01, 0.001));
        let req = Request {
            id: 99,
            arrival: 5.0,
            model: ModelKind::Llama2_70B,
            origin: Region::EastUs,
            tier: Tier::IwF,
            app: crate::trace::types::AppKind::Chat,
            input_tokens: 100,
            output_tokens: 10,
        };
        sim.now = 100.0;
        sim.on_request_killed(req);
        // Parked with its ORIGINAL arrival time (SLA clock keeps running).
        assert_eq!(sim.pending_retries[&99].arrival, 5.0);
        assert_eq!(sim.retry_attempt[&99], 1);
        // First backoff: base (1 s) after the kill instant.
        let due = loop {
            let (t, ev) = sim.events.pop().unwrap();
            if let Event::RetryDue { id } = ev {
                assert_eq!(id, 99);
                break t;
            }
        };
        assert_eq!(due, 100.0 + sim.cfg.faults.retry.backoff(1));
        // Second kill doubles the backoff; past max_attempts it is lost.
        sim.now = 101.0;
        let r = sim.pending_retries.remove(&99).unwrap();
        sim.on_request_killed(r);
        assert_eq!(sim.retry_attempt[&99], 2);
        for _ in 0..10 {
            if let Some(r) = sim.pending_retries.remove(&99) {
                sim.on_request_killed(r);
            }
        }
        assert_eq!(sim.metrics.failures.lost_total(), 1, "exhausted retries must be lost");
        assert!(!sim.retry_attempt.contains_key(&99), "loss drops the bookkeeping");
        assert_eq!(sim.pending_retries.len(), 0);
    }

    #[test]
    fn fault_run_conserves_every_request_and_recovers() {
        let mut cfg = quick_config(Strategy::Reactive, 0.1, 0.005);
        cfg.scaling.max_instances = 10;
        // Region outage mid-trace, a spot shock after it, and a steady
        // crash hazard — every fault class at once.
        cfg.faults = FaultPlan::region_dark(Region::EastUs, 2000.0, 5000.0);
        cfg.faults.spot_shocks.push(crate::sim::faults::SpotShock { at: 6000.0, frac: 0.5 });
        cfg.faults.crash_rate_per_day = 2.0;
        let sim = run_simulation(cfg);

        let gen = TraceGenerator::new(sim.cfg.trace.clone());
        let total = gen.stream().count() as u64;
        let f = &sim.metrics.failures;
        assert!(f.killed_total() > 0, "the outage must kill in-flight work");
        assert_eq!(
            sim.metrics.completed + sim.metrics.dropped + f.lost_total() + f.shed_total(),
            total,
            "every request must complete, drop, be lost, or be shed — exactly once"
        );
        assert_eq!(f.shed_interactive_total(), 0, "only NIW work may ever be shed");
        // The outage incident is recorded with its window end, and the
        // region recovered to its pre-outage capacity after the window.
        let outage = f
            .incidents
            .iter()
            .find(|i| i.kind == "region-outage")
            .expect("outage incident recorded");
        assert_eq!(outage.region, Region::EastUs);
        assert_eq!(outage.start, 2000.0);
        assert_eq!(outage.fault_end, Some(5000.0));
        let ttr = outage.time_to_recover().expect("capacity must recover");
        assert!(ttr >= 3000.0, "cannot recover before the window lifts: {ttr}");
        assert!(sim.cluster.region_available(Region::EastUs));
        assert!(sim.cluster.aggregates_consistent());
        // Retry amplification is measurable and sane.
        let amp = f.retry_amplification(sim.metrics.completed);
        assert!(amp >= 1.0 && amp < 2.0, "retry amplification {amp}");
    }

    #[test]
    fn fault_runs_are_deterministic() {
        let mk = || {
            let mut cfg = quick_config(Strategy::LtUa, 0.1, 0.005);
            cfg.scaling.max_instances = 10;
            cfg.faults = FaultPlan::region_dark(Region::CentralUs, 2000.0, 4000.0);
            cfg.faults.crash_rate_per_day = 2.0;
            cfg
        };
        let a = run_simulation(mk());
        let b = run_simulation(mk());
        assert!(a.metrics == b.metrics, "fault injection must replay identically");
        assert!(a.metrics.failures.killed_total() > 0);
    }

    #[test]
    fn disagg_run_conserves_and_hands_off() {
        let mut cfg = quick_config(Strategy::LtUa, 0.1, 0.005);
        cfg.scaling.max_instances = 10;
        cfg.disagg = DisaggParams::enabled();
        let sim = run_simulation(cfg);
        let total = TraceGenerator::new(sim.cfg.trace.clone()).stream().count() as u64;
        assert!(sim.metrics.handoffs > 0, "disagg run must hand off prefills");
        assert!(sim.metrics.kv_transfer_secs > 0.0, "KV migration must be charged");
        assert_eq!(
            sim.metrics.completed + sim.metrics.dropped,
            total,
            "every request must complete or be explicitly dropped"
        );
        assert_eq!(
            sim.metrics.handoffs,
            sim.metrics.handoff_admissions + sim.metrics.handoff_drops,
            "every handoff must be admitted or dropped — exactly once"
        );
        assert!(sim.pending_handoffs.is_empty(), "no handoff may be left parked");
        assert!(sim.inflight_decode.is_empty(), "no decode TTFT may be left dangling");
        assert!(sim.cluster.aggregates_consistent());
        // ITL is live as a first-class streaming metric.
        assert!(sim.metrics.itl_p95() > 0.0);
        assert_eq!(sim.metrics.itl_attainment(f64::INFINITY), 1.0);
    }

    #[test]
    fn disagg_runs_are_deterministic() {
        let mk = || {
            let mut cfg = quick_config(Strategy::LtUa, 0.1, 0.005);
            cfg.scaling.max_instances = 10;
            cfg.disagg = DisaggParams::enabled();
            cfg
        };
        let a = run_simulation(mk());
        let b = run_simulation(mk());
        assert!(a.metrics == b.metrics, "disagg runs must replay identically");
        assert!(a.metrics.handoffs > 0);
    }

    #[test]
    fn unified_run_keeps_disagg_counters_at_zero() {
        let sim = run_quick(Strategy::LtUa);
        assert_eq!(sim.metrics.handoffs, 0);
        assert_eq!(sim.metrics.handoff_admissions, 0);
        assert_eq!(sim.metrics.handoff_drops, 0);
        assert_eq!(sim.metrics.kv_transfer_secs, 0.0);
    }

    #[test]
    fn empty_control_fault_plan_is_bit_identical() {
        // Two identity claims: (a) the empty control-fault plan takes
        // the untouched `run_epoch` branch; (b) a *non-empty* plan whose
        // windows never open routes every epoch through
        // `run_epoch_modded` with clean mods — which must still be
        // bit-identical (every modifier is branch-gated; no identity
        // arithmetic anywhere on the clean path).
        let reference = run_quick(Strategy::LtUa);

        let mut cfg = quick_config(Strategy::LtUa, 0.1, 0.005);
        cfg.scaling.max_instances = 10;
        cfg.control_faults = ControlFaultPlan::parse("").unwrap();
        assert!(cfg.control_faults.is_empty());
        let sim = run_simulation(cfg);
        assert!(sim.metrics == reference.metrics);
        assert!(sim.metrics.guardrails.is_empty());

        let mut cfg = quick_config(Strategy::LtUa, 0.1, 0.005);
        cfg.scaling.max_instances = 10;
        // Every fault class armed — all far beyond the 0.1-day horizon.
        cfg.control_faults = ControlFaultPlan::parse(
            "forecast-blackout=100d-101d;telemetry-freeze=100d-101d;\
             solver-fail=100d-101d;act-drop=100d-101d;act-delay=60s@100d-101d",
        )
        .unwrap();
        assert!(!cfg.control_faults.is_empty());
        let sim = run_simulation(cfg);
        assert!(sim.metrics == reference.metrics);
        assert!(sim.metrics.guardrails.is_empty());
    }

    #[test]
    fn guarded_run_without_faults_stays_fresh() {
        let mut cfg = quick_config(Strategy::LtUa, 0.1, 0.005);
        cfg.scaling.max_instances = 10;
        cfg.guardrails = GuardrailParams::enabled();
        let sim = run_simulation(cfg);
        assert!(sim.metrics.completed > 0);
        let g = &sim.metrics.guardrails;
        assert!(g.epochs_fresh > 0, "healthy guarded epochs must count as fresh");
        assert_eq!(g.epochs_held, 0);
        assert_eq!(g.epochs_reactive, 0);
        assert_eq!(g.degraded_secs, 0.0, "no fault, no degraded time");
        assert_eq!(g.transition_count(), 0);
        assert_eq!(sim.guardrail.mode, GuardrailMode::Fresh);
    }

    #[test]
    fn guarded_blackout_walks_the_cascade_and_is_deterministic() {
        // Quick trace: control epochs fire at t = 0, 3600 and 7200; a
        // blackout over the last two walks Fresh → Held → Reactive with
        // the held budget cut to one epoch.
        let mk = || {
            let mut cfg = quick_config(Strategy::LtUa, 0.1, 0.005);
            cfg.scaling.max_instances = 10;
            cfg.control_faults = ControlFaultPlan::forecast_blackout(3000.0, 8000.0);
            cfg.guardrails = GuardrailParams::enabled();
            cfg.guardrails.max_held_epochs = 1;
            cfg
        };
        let sim = run_simulation(mk());
        let g = &sim.metrics.guardrails;
        assert_eq!(g.blackout_epochs, 2, "epochs at 3600 and 7200 are dark");
        assert_eq!(g.epochs_held, 1);
        assert_eq!(g.epochs_reactive, 1);
        assert_eq!(g.degraded_secs, 2.0 * sim.cfg.scaling.control_interval);
        assert_eq!(g.transition_count(), 2, "Fresh→Held, Held→Reactive");
        assert_eq!(g.transitions[0].cause, "forecast-blackout");
        assert_eq!(g.transitions[1].cause, "held-expired");
        // Request accounting survives the degraded control plane.
        let total = TraceGenerator::new(sim.cfg.trace.clone()).stream().count() as u64;
        assert_eq!(sim.metrics.completed + sim.metrics.dropped, total);

        let again = run_simulation(mk());
        assert!(sim.metrics == again.metrics, "guarded fault runs must replay identically");
    }

    #[test]
    fn naive_blackout_counts_exposure_but_never_degrades() {
        // Same schedule, guardrails off: the naive controller consumes
        // the zeroed forecasts as truth — exposure counters tick, but no
        // rung change and no degraded time (there is no cascade to walk).
        let mut cfg = quick_config(Strategy::LtUa, 0.1, 0.005);
        cfg.scaling.max_instances = 10;
        cfg.control_faults = ControlFaultPlan::forecast_blackout(3000.0, 8000.0);
        let sim = run_simulation(cfg);
        let g = &sim.metrics.guardrails;
        assert_eq!(g.blackout_epochs, 2);
        assert_eq!(g.degraded_secs, 0.0);
        assert_eq!(g.transition_count(), 0);
        assert_eq!(g.epochs_fresh + g.epochs_held + g.epochs_reactive, 0);
        let total = TraceGenerator::new(sim.cfg.trace.clone()).stream().count() as u64;
        assert_eq!(sim.metrics.completed + sim.metrics.dropped, total);
    }

    #[test]
    fn actuation_faults_are_counted() {
        let mut cfg = quick_config(Strategy::Reactive, 0.1, 0.005);
        cfg.scaling.max_instances = 10;
        // Dropped scale-outs over one stretch, delayed ones over another.
        cfg.control_faults =
            ControlFaultPlan::parse("act-drop=1000s-3000s;act-delay=120s@4000s-8000s").unwrap();
        let sim = run_simulation(cfg);
        let g = &sim.metrics.guardrails;
        assert!(
            g.actuations_dropped > 0 || g.actuations_delayed > 0,
            "a loaded reactive run must attempt scale-outs inside the windows"
        );
        let total = TraceGenerator::new(sim.cfg.trace.clone()).stream().count() as u64;
        assert_eq!(sim.metrics.completed + sim.metrics.dropped, total);
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run_quick(Strategy::LtUa);
        let b = run_quick(Strategy::LtUa);
        // Full streaming-state equality: every accumulator cell,
        // histogram bucket and ledger point.
        assert!(a.metrics == b.metrics, "identical configs must replay identically");
        let ih_a = a.instance_hours(ModelKind::Llama2_70B);
        let ih_b = b.instance_hours(ModelKind::Llama2_70B);
        assert!((ih_a - ih_b).abs() < 1e-9);
    }
}
