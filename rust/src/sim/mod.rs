//! The cloud-scale discrete-event simulator (our SplitWise extension —
//! §7.1 of the paper).
//!
//! * [`event`] — time-ordered event queue.
//! * [`instance`] — one LLM model instance: continuous batching in decode
//!   chunks, KV-memory accounting, the effective-utilization signal.
//! * [`cluster`] — regions, endpoints, VM budgets, the spot pool, and
//!   provisioning delays.
//! * [`engine`] — the simulation loop wiring traces, routing, the queue
//!   manager, autoscalers and metrics together.
//! * [`chunked`] — epoch-sliced chunked execution of a single run:
//!   pipelined generation, explicit state handoff at every boundary,
//!   bit-identical to the sequential engine.
//! * [`faults`] — the deterministic fault plane: declarative,
//!   counter-seeded schedules of region outages, VM crashes, spot
//!   preemption shocks and latency degradation.

pub mod chunked;
pub mod cluster;
pub mod engine;
pub mod event;
pub mod faults;
pub mod instance;

pub use chunked::{run_chunked, run_simulation_chunked, ChunkedOptions};
pub use cluster::{Cluster, InstanceId, PoolTag};
pub use engine::{SimConfig, SimHandoff, Simulation, Strategy};
pub use event::{Event, EventQueue};
pub use faults::{ControlFaultPlan, FaultPlan, RetryPolicy};
pub use instance::{InstState, InstanceSim};
