//! Cluster substrate: regions, model endpoints, VM budgets, the spot pool
//! and provisioning delays (§2.3).
//!
//! Scale-out sources, fastest first (§6.4):
//! 1. a spot instance already hosting the same model type (≈1 min),
//! 2. a spot instance of another model type — weights must be redeployed
//!    (≈10 min local),
//! 3. a fresh VM from the regional budget (≈10 min local; 2 h if the
//!    weights are not in the region's repository).
//!
//! Scale-in drains the least-loaded instance and donates it to the spot
//! pool (§2.3: a lost-opportunity sink that SageServe tries to shrink).
//! Donated hours earn the per-SKU spot-market price
//! ([`crate::config::SpotMarket`]); the autoscaler's unpinned scale-out
//! first reclaims donated VMs most-valuable-SKU-first
//! ([`Cluster::gpus_spot_desc`]) before burning fresh-VM budget
//! cheapest-SKU-first.
//!
//! ## Incremental accounting
//!
//! Every per-endpoint quantity the hot path reads — effective memory
//! utilization, waiting-aware utilization, pending tokens, active-instance
//! counts, the engine's all-idle check — is maintained *incrementally* at
//! the point of mutation instead of being recomputed by scanning
//! instances.  All instance mutations flow through [`Cluster::mutate`]
//! (or the specialised [`Cluster::plan_next_chunk`]), which snapshots the
//! instance's contribution before the change and applies the delta to the
//! owning endpoint's [`PoolAgg`] afterwards.  `effective_util`,
//! `effective_util_with_waiting`, `pool_util`, `pending_tokens` and
//! `is_all_idle` are therefore O(1) regardless of cluster size.
//! [`Cluster::aggregates_consistent`] recounts everything from scratch
//! for tests.

use crate::config::{DisaggParams, FleetSpec, GpuKind, ModelKind, Region, ScalingParams, Time};
use crate::coordinator::scheduler::SchedPolicy;
use crate::metrics::Metrics;
use crate::perf::PerfTable;
use crate::sim::instance::{ChunkPlan, CrashedWork, InstState, InstanceSim, Phase};
use crate::trace::types::Request;
use std::collections::BTreeMap;
use std::ops::Index;

/// Index into [`Cluster::instances`] — stable for the VM's whole life.
pub type InstanceId = usize;

/// Which workload pool an instance belongs to.  `Unified` strategies use
/// one pool; the Siloed baseline splits IW/NIW (§4); Chiron uses its
/// interactive/mixed/batch trio [34].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum PoolTag {
    /// One pool for all tiers (SageServe and the Reactive baseline).
    Unified,
    /// Siloed baseline: the interactive-only pool.
    SiloIw,
    /// Siloed baseline: the non-interactive-only pool.
    SiloNiw,
    /// Chiron: the interactive pool.
    ChironInteractive,
    /// Chiron: the mixed pool (serves both tiers).
    ChironMixed,
    /// Chiron: the batch pool (NIW only).
    ChironBatch,
}

impl PoolTag {
    /// Every pool tag, in [`PoolTag::index`] order.
    pub const ALL: [PoolTag; 6] = [
        PoolTag::Unified,
        PoolTag::SiloIw,
        PoolTag::SiloNiw,
        PoolTag::ChironInteractive,
        PoolTag::ChironMixed,
        PoolTag::ChironBatch,
    ];

    /// Dense index for per-pool aggregate slots.
    pub fn index(self) -> usize {
        match self {
            PoolTag::Unified => 0,
            PoolTag::SiloIw => 1,
            PoolTag::SiloNiw => 2,
            PoolTag::ChironInteractive => 3,
            PoolTag::ChironMixed => 4,
            PoolTag::ChironBatch => 5,
        }
    }

    /// May this pool serve interactive requests?
    pub fn serves_iw(self) -> bool {
        !matches!(self, PoolTag::SiloNiw | PoolTag::ChironBatch)
    }

    /// May this pool serve non-interactive requests?
    pub fn serves_niw(self) -> bool {
        !matches!(self, PoolTag::SiloIw | PoolTag::ChironInteractive)
    }
}

/// Incrementally-maintained sums over the *active* instances of one
/// (endpoint, pool) — the O(1) backing store for every utilization and
/// backpressure signal the routing/scaling hot path reads.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PoolAgg {
    /// Σ reserved KV tokens across the pool's active instances.
    pub kv_used: u64,
    /// Σ KV serving budgets (the denominator of effective utilization).
    pub kv_capacity: u64,
    /// Σ queued-but-unadmitted tokens.
    pub waiting_tokens: u64,
    /// Σ queued + running tokens (the JSQ backpressure signal).
    pub pending_tokens: u64,
    /// Number of active instances in this pool.
    pub count: usize,
    /// Active-instance counts split by GPU SKU (Σ == `count`) — the O(1)
    /// per-SKU signal the heterogeneity-aware scaling paths read.
    pub count_by_gpu: [usize; GpuKind::COUNT],
    /// `kv_used` split by GPU SKU (Σ == `kv_used`) — with
    /// `kv_capacity_by_gpu`, the O(1) per-SKU headroom signal SKU-aware
    /// region routing reads.
    pub kv_used_by_gpu: [u64; GpuKind::COUNT],
    /// `kv_capacity` split by GPU SKU (Σ == `kv_capacity`).
    pub kv_capacity_by_gpu: [u64; GpuKind::COUNT],
}

/// Per-(model, region) endpoint bookkeeping.
#[derive(Debug, Default, Clone)]
pub struct Endpoint {
    /// Instances allocated to this endpoint (any state except Spot).
    pub instances: Vec<InstanceId>,
    /// Roster cache: instances whose pool may serve interactive traffic
    /// (same relative order as `instances` — JSQ tie-breaks match).
    pub iw_instances: Vec<InstanceId>,
    /// Roster cache: instances whose pool may serve NIW traffic.
    pub niw_instances: Vec<InstanceId>,
    /// Roster cache: instances running the prefill phase (empty unless
    /// disaggregation is enabled — unified fleets never populate it, so
    /// the disagg-off engine walks zero extra entries).
    pub prefill_instances: Vec<InstanceId>,
    /// Roster cache: instances running the decode phase (empty unless
    /// disaggregation is enabled).
    pub decode_instances: Vec<InstanceId>,
    /// Last reactive scaling event (cooldown enforcement).
    pub last_scale: Time,
    /// LT-U / LT-UA deferred target from the last control epoch.
    pub target: Option<usize>,
    /// Forecast max TPS for the current hour (LT-UA gap checks).
    pub forecast_tps: f64,
    /// LT-U / LT-UA per-SKU targets from the last control epoch, indexed
    /// by `GpuKind::index` (only fleet SKUs are `Some`).
    pub target_by_gpu: [Option<usize>; GpuKind::COUNT],
    /// Active-instance aggregates, one slot per [`PoolTag`].
    pub agg: [PoolAgg; 6],
    /// Allocated (provisioning + active + draining) instance counts per
    /// GPU SKU — the controller's per-SKU n_{j,k}, maintained by the
    /// roster add/remove paths.  O(1) reads.
    pub alloc_by_gpu: [usize; GpuKind::COUNT],
}

impl Endpoint {
    /// Sum one field across the six pool slots (still O(1): six adds).
    fn totals(&self) -> PoolAgg {
        let mut t = PoolAgg::default();
        for a in &self.agg {
            t.kv_used += a.kv_used;
            t.kv_capacity += a.kv_capacity;
            t.waiting_tokens += a.waiting_tokens;
            t.pending_tokens += a.pending_tokens;
            t.count += a.count;
            for k in 0..GpuKind::COUNT {
                t.count_by_gpu[k] += a.count_by_gpu[k];
                t.kv_used_by_gpu[k] += a.kv_used_by_gpu[k];
                t.kv_capacity_by_gpu[k] += a.kv_capacity_by_gpu[k];
            }
        }
        t
    }
}

/// Dense (model, region) → [`Endpoint`] storage: a flat `Vec` plus an
/// O(1) index grid, replacing the `BTreeMap` the per-request hot path
/// used to walk.  The API mirrors the map it replaced (`get`, `get_mut`,
/// `keys`, `values`, `iter`, `Index`), so call sites read the same.
#[derive(Debug, Default)]
pub struct EndpointMap {
    keys: Vec<(ModelKind, Region)>,
    eps: Vec<Endpoint>,
    /// `lookup[model.index()][region.index()]` → slot in `eps`.
    lookup: [[Option<u8>; 3]; 6],
}

impl EndpointMap {
    #[inline]
    fn slot(&self, model: ModelKind, region: Region) -> Option<usize> {
        self.lookup[model.index()][region.index()].map(|s| s as usize)
    }

    /// Insert or replace the endpoint at `key`.
    pub fn insert(&mut self, key: (ModelKind, Region), ep: Endpoint) {
        if let Some(s) = self.slot(key.0, key.1) {
            self.eps[s] = ep;
            return;
        }
        debug_assert!(self.eps.len() < u8::MAX as usize);
        self.lookup[key.0.index()][key.1.index()] = Some(self.eps.len() as u8);
        self.keys.push(key);
        self.eps.push(ep);
    }

    /// O(1) endpoint lookup.
    #[inline]
    pub fn get(&self, key: &(ModelKind, Region)) -> Option<&Endpoint> {
        self.slot(key.0, key.1).map(|s| &self.eps[s])
    }

    /// O(1) mutable endpoint lookup.
    #[inline]
    pub fn get_mut(&mut self, key: &(ModelKind, Region)) -> Option<&mut Endpoint> {
        match self.slot(key.0, key.1) {
            Some(s) => Some(&mut self.eps[s]),
            None => None,
        }
    }

    /// The endpoint keys, insertion (dense-slot) order.
    pub fn keys(&self) -> impl Iterator<Item = &(ModelKind, Region)> + '_ {
        self.keys.iter()
    }

    /// Key at a dense slot index (`0..len()`): the allocation-free
    /// endpoint walk — callers iterate `0..len()` and read each key by
    /// value instead of collecting a `Vec` of keys per tick.
    #[inline]
    pub fn key_at(&self, idx: usize) -> (ModelKind, Region) {
        self.keys[idx]
    }

    /// The endpoints, dense-slot order (matches [`EndpointMap::keys`]).
    pub fn values(&self) -> impl Iterator<Item = &Endpoint> + '_ {
        self.eps.iter()
    }

    /// (key, endpoint) pairs, dense-slot order.
    pub fn iter(&self) -> impl Iterator<Item = (&(ModelKind, Region), &Endpoint)> + '_ {
        self.keys.iter().zip(self.eps.iter())
    }

    /// Number of endpoints (fixed after cluster construction).
    pub fn len(&self) -> usize {
        self.eps.len()
    }

    /// True when no endpoint has been inserted.
    pub fn is_empty(&self) -> bool {
        self.eps.is_empty()
    }
}

impl<'a> Index<&'a (ModelKind, Region)> for EndpointMap {
    type Output = Endpoint;

    fn index(&self, key: &'a (ModelKind, Region)) -> &Endpoint {
        self.get(key)
            .unwrap_or_else(|| panic!("unknown endpoint ({}, {})", key.0, key.1))
    }
}

/// What one instance contributes to its endpoint's aggregates — captured
/// before a mutation, re-captured after, delta applied.
#[derive(Debug, Clone, Copy)]
struct InstSnapshot {
    model: ModelKind,
    region: Region,
    pool: PoolTag,
    gpu: GpuKind,
    active: bool,
    busy: bool,
    kv_used: u64,
    kv_capacity: u64,
    waiting_tokens: u64,
    pending_tokens: u64,
}

/// The multi-region cluster state.
pub struct Cluster {
    /// Every VM the simulation ever created, indexed by [`InstanceId`]
    /// (instances are never removed, only change state).
    pub instances: Vec<InstanceSim>,
    /// Per-(model, region) endpoint bookkeeping and aggregates.
    pub endpoints: EndpointMap,
    /// Donated instances per region (still hosting their last model).
    pub spot_pool: BTreeMap<Region, Vec<InstanceId>>,
    /// Remaining un-allocated VMs per `[region][gpu]` (fresh VMs are
    /// provisioned on a specific SKU).
    pub vm_budget: [[usize; GpuKind::COUNT]; 3],
    /// The fleet's SKUs, fleet order — the per-SKU axis the controller's
    /// `CapacityInputs` columns and `EpochPlan` deltas align with.
    pub gpus: Vec<GpuKind>,
    /// Fleet SKUs by ascending $/h (stable: cost ties keep fleet order),
    /// computed once — the cheapest-first scale-out order.
    pub gpus_cost_asc: Vec<GpuKind>,
    /// `gpus_cost_asc` reversed — the most-expensive-first scale-in order.
    pub gpus_cost_desc: Vec<GpuKind>,
    /// Fleet SKUs by descending spot-market value
    /// ([`GpuKind::spot_dollars_per_hour`]; stable, ties keep fleet
    /// order) — the most-valuable-first spot *reclaim* order the
    /// autoscaler uses before it falls back to fresh provisioning.
    pub gpus_spot_desc: Vec<GpuKind>,
    /// Fleet SKUs by descending HBM ([`GpuKind::hbm_gib`]; stable, ties
    /// keep fleet order) — the SKU-affinity cascade for long-context
    /// routing.
    pub gpus_hbm_desc: Vec<GpuKind>,
    /// True when the fleet spans more than one HBM size.  Gates the
    /// long-context HBM affinity: on an HBM-uniform fleet (e.g. 50/50
    /// H100+A100, both 640 GiB) "prefer the high-HBM SKU" would just
    /// chase the tie-break SKU for no memory benefit, so the router
    /// treats long-context requests like short ones there.
    pub hbm_diverse: bool,
    /// Models whose weights are present in each region's repository
    /// (missing ⇒ 2 h remote redeploy).
    pub local_weights: BTreeMap<Region, Vec<ModelKind>>,
    /// Per-(model, SKU) performance profiles for this fleet.
    pub perf: PerfTable,
    /// Provisioning and scaling constants (§2.3, §4, §6).
    pub params: ScalingParams,
    /// Prefill/decode disaggregation policy.  Off by default; flipped on
    /// (and the live roster partitioned) via [`Cluster::set_disagg`].
    pub disagg: DisaggParams,
    /// Instances with a non-empty batch or waiting queue — the engine's
    /// O(1) all-idle check.
    busy_instances: usize,
    /// Fault-plane availability mask: regions currently dark (inside an
    /// outage window), indexed by [`Region::index`].  Dark regions are
    /// excluded from routing and refuse provisioning.
    dark: [bool; 3],
    /// Regions under cross-region latency degradation, indexed by
    /// [`Region::index`] — routable, but retries prefer clean regions.
    degraded: [bool; 3],
    /// Extra per-request latency (seconds) charged while a region is
    /// degraded, indexed by [`Region::index`].
    extra_latency: [f64; 3],
}

impl Cluster {
    /// Build a homogeneous cluster (every instance on the perf table's
    /// primary SKU) with `initial_per_endpoint` active instances per
    /// (model, region) pool tag, plus `vm_budget_per_region` spare VMs.
    pub fn new(
        models: &[ModelKind],
        perf: PerfTable,
        params: ScalingParams,
        pools: &[(PoolTag, usize)],
        vm_budget_per_region: usize,
    ) -> Self {
        let fleet = FleetSpec::homogeneous(perf.primary_gpu());
        Self::new_fleet(models, perf, params, pools, vm_budget_per_region, &fleet)
    }

    /// Build a cluster over an explicit GPU fleet: each pool's initial
    /// count AND the per-region fresh-VM budget are split across SKUs by
    /// the fleet weights, so a mixed fleet gets the same total resources
    /// as a homogeneous one (fair cost comparisons).
    pub fn new_fleet(
        models: &[ModelKind],
        perf: PerfTable,
        params: ScalingParams,
        pools: &[(PoolTag, usize)],
        vm_budget_per_region: usize,
        fleet: &FleetSpec,
    ) -> Self {
        let gpus = perf.gpus().to_vec();
        let mut vm_budget = [[0usize; GpuKind::COUNT]; 3];
        for (g, share) in fleet.split(vm_budget_per_region) {
            debug_assert!(gpus.contains(&g), "fleet SKU missing from perf table");
            for region in vm_budget.iter_mut() {
                region[g.index()] = share;
            }
        }
        let mut gpus_cost_asc = gpus.clone();
        gpus_cost_asc
            .sort_by(|a, b| a.dollars_per_hour().partial_cmp(&b.dollars_per_hour()).unwrap());
        let mut gpus_cost_desc = gpus_cost_asc.clone();
        gpus_cost_desc.reverse();
        let mut gpus_spot_desc = gpus.clone();
        gpus_spot_desc.sort_by(|a, b| {
            b.spot_dollars_per_hour().partial_cmp(&a.spot_dollars_per_hour()).unwrap()
        });
        let mut gpus_hbm_desc = gpus.clone();
        gpus_hbm_desc.sort_by(|a, b| b.hbm_gib().partial_cmp(&a.hbm_gib()).unwrap());
        let hbm_diverse = gpus_hbm_desc.first().map(|g| g.hbm_gib())
            != gpus_hbm_desc.last().map(|g| g.hbm_gib());
        let mut cluster = Cluster {
            instances: Vec::new(),
            endpoints: EndpointMap::default(),
            spot_pool: Region::ALL.iter().map(|&r| (r, Vec::new())).collect(),
            vm_budget,
            gpus,
            gpus_cost_asc,
            gpus_cost_desc,
            gpus_spot_desc,
            gpus_hbm_desc,
            hbm_diverse,
            local_weights: Region::ALL.iter().map(|&r| (r, models.to_vec())).collect(),
            perf,
            params,
            disagg: DisaggParams::default(),
            busy_instances: 0,
            dark: [false; 3],
            degraded: [false; 3],
            extra_latency: [0.0; 3],
        };
        for &model in models {
            for region in Region::ALL {
                cluster.endpoints.insert((model, region), Endpoint::default());
                for &(pool, count) in pools {
                    for (gpu, n) in fleet.split(count) {
                        for _ in 0..n {
                            cluster.spawn_instance(model, region, pool, gpu, InstState::Active);
                        }
                    }
                }
            }
        }
        cluster
    }

    fn spawn_instance(
        &mut self,
        model: ModelKind,
        region: Region,
        pool: PoolTag,
        gpu: GpuKind,
        state: InstState,
    ) -> InstanceId {
        let id = self.instances.len();
        let kv_cap = self.perf.profile(model, gpu).serving_kv_budget();
        self.instances
            .push(InstanceSim::new(id, model, region, pool, gpu, state, kv_cap));
        self.roster_add(model, region, pool, id);
        // A freshly spawned instance had no prior contribution: apply its
        // delta against an empty "ghost" snapshot.
        let ghost = InstSnapshot {
            model,
            region,
            pool,
            gpu,
            active: false,
            busy: false,
            kv_used: 0,
            kv_capacity: 0,
            waiting_tokens: 0,
            pending_tokens: 0,
        };
        self.apply_delta(id, ghost);
        id
    }

    /// Phase for the next instance joining an endpoint that currently
    /// has `n_before` rostered instances, `prefill_before` of them
    /// prefill: keep the prefill share tracking the configured fraction
    /// while guaranteeing at least one instance of each phase once the
    /// endpoint holds two or more.  A one-instance endpoint stays
    /// `Unified` (it serves both phases in place — a lone prefill VM
    /// would strand every handoff).
    fn next_phase(&self, n_before: usize, prefill_before: usize) -> Phase {
        if !self.disagg.enabled {
            return Phase::Unified;
        }
        let n_after = n_before + 1;
        if n_after < 2 {
            return Phase::Unified;
        }
        let want = ((n_after as f64) * self.disagg.prefill_fraction).ceil() as usize;
        let want = want.max(1).min(n_after - 1);
        if prefill_before < want {
            Phase::Prefill
        } else {
            Phase::Decode
        }
    }

    fn roster_add(&mut self, model: ModelKind, region: Region, pool: PoolTag, id: InstanceId) {
        let gpu = self.instances[id].gpu;
        let (already, n_before, prefill_before) = {
            let ep = self.endpoints.get(&(model, region)).unwrap();
            (ep.instances.contains(&id), ep.instances.len(), ep.prefill_instances.len())
        };
        if already {
            return;
        }
        let phase = self.next_phase(n_before, prefill_before);
        // Phase is not part of the aggregate snapshot, so the direct
        // write is coherent without a `mutate` round-trip.
        self.instances[id].phase = phase;
        let ep = self.endpoints.get_mut(&(model, region)).unwrap();
        ep.instances.push(id);
        ep.alloc_by_gpu[gpu.index()] += 1;
        if pool.serves_iw() {
            ep.iw_instances.push(id);
        }
        if pool.serves_niw() {
            ep.niw_instances.push(id);
        }
        match phase {
            Phase::Prefill => ep.prefill_instances.push(id),
            Phase::Decode => ep.decode_instances.push(id),
            Phase::Unified => {}
        }
    }

    fn roster_remove(&mut self, model: ModelKind, region: Region, id: InstanceId) {
        let gpu = self.instances[id].gpu;
        if let Some(ep) = self.endpoints.get_mut(&(model, region)) {
            if let Some(pos) = ep.instances.iter().position(|&x| x == id) {
                ep.instances.remove(pos);
                ep.alloc_by_gpu[gpu.index()] -= 1;
            }
            ep.iw_instances.retain(|&x| x != id);
            ep.niw_instances.retain(|&x| x != id);
            ep.prefill_instances.retain(|&x| x != id);
            ep.decode_instances.retain(|&x| x != id);
        }
        // The instance keeps its phase tag while de-rostered (the engine
        // still reads it when classifying a crashed VM's finished work);
        // `roster_add` re-assigns a fresh phase on any later reclaim.
    }

    /// Flip the disaggregation policy on a freshly built cluster and
    /// deterministically partition every endpoint's roster: the first
    /// `ceil(fraction · n)` instances (roster order) become prefill, the
    /// rest decode, with at least one of each phase wherever `n ≥ 2`.
    /// A disabled `params` leaves the cluster untouched — the unified
    /// engine never calls into any phase path.
    pub fn set_disagg(&mut self, params: DisaggParams) {
        self.disagg = params;
        if !self.disagg.enabled {
            return;
        }
        for s in 0..self.endpoints.len() {
            let key = self.endpoints.key_at(s);
            let ids = self.endpoints[&key].instances.clone();
            let n = ids.len();
            if n < 2 {
                continue; // lone instance stays Unified (see next_phase)
            }
            let want = ((n as f64) * self.disagg.prefill_fraction).ceil() as usize;
            let want = want.max(1).min(n - 1);
            let mut prefill = Vec::with_capacity(want);
            let mut decode = Vec::with_capacity(n - want);
            for (k, &id) in ids.iter().enumerate() {
                let phase = if k < want { Phase::Prefill } else { Phase::Decode };
                self.instances[id].phase = phase;
                if phase == Phase::Prefill {
                    prefill.push(id);
                } else {
                    decode.push(id);
                }
            }
            let ep = self.endpoints.get_mut(&key).unwrap();
            ep.prefill_instances = prefill;
            ep.decode_instances = decode;
        }
    }

    /// Allocated instance counts per GPU SKU for one phase of an
    /// endpoint — the controller's per-phase n_{j,k}.  Walks the phase
    /// roster (a handful of entries, once per control epoch).
    pub fn phase_alloc_by_gpu(
        &self,
        model: ModelKind,
        region: Region,
        phase: Phase,
    ) -> [usize; GpuKind::COUNT] {
        let mut out = [0usize; GpuKind::COUNT];
        if let Some(ep) = self.endpoints.get(&(model, region)) {
            let roster = match phase {
                Phase::Prefill => &ep.prefill_instances,
                Phase::Decode => &ep.decode_instances,
                Phase::Unified => &ep.instances,
            };
            for &i in roster {
                out[self.instances[i].gpu.index()] += 1;
            }
        }
        out
    }

    fn snapshot(&self, id: InstanceId) -> InstSnapshot {
        let i = &self.instances[id];
        InstSnapshot {
            model: i.model,
            region: i.region,
            pool: i.pool,
            gpu: i.gpu,
            active: i.state == InstState::Active,
            busy: !i.batch.is_empty() || !i.waiting.is_empty(),
            kv_used: i.kv_used,
            kv_capacity: i.kv_capacity,
            waiting_tokens: i.waiting_tokens(),
            pending_tokens: i.pending_tokens(),
        }
    }

    /// Subtract the before-contribution and add the after-contribution to
    /// the owning endpoint's aggregates (a handful of integer ops).
    fn apply_delta(&mut self, id: InstanceId, before: InstSnapshot) {
        let after = self.snapshot(id);
        if before.busy != after.busy {
            if after.busy {
                self.busy_instances += 1;
            } else {
                self.busy_instances -= 1;
            }
        }
        if before.active {
            let ep = self
                .endpoints
                .get_mut(&(before.model, before.region))
                .expect("endpoint for active instance");
            let a = &mut ep.agg[before.pool.index()];
            a.kv_used -= before.kv_used;
            a.kv_capacity -= before.kv_capacity;
            a.waiting_tokens -= before.waiting_tokens;
            a.pending_tokens -= before.pending_tokens;
            a.count -= 1;
            a.count_by_gpu[before.gpu.index()] -= 1;
            a.kv_used_by_gpu[before.gpu.index()] -= before.kv_used;
            a.kv_capacity_by_gpu[before.gpu.index()] -= before.kv_capacity;
        }
        if after.active {
            let ep = self
                .endpoints
                .get_mut(&(after.model, after.region))
                .expect("endpoint for active instance");
            let a = &mut ep.agg[after.pool.index()];
            a.kv_used += after.kv_used;
            a.kv_capacity += after.kv_capacity;
            a.waiting_tokens += after.waiting_tokens;
            a.pending_tokens += after.pending_tokens;
            a.count += 1;
            a.count_by_gpu[after.gpu.index()] += 1;
            a.kv_used_by_gpu[after.gpu.index()] += after.kv_used;
            a.kv_capacity_by_gpu[after.gpu.index()] += after.kv_capacity;
        }
    }

    /// Run a mutating closure on one instance, keeping the endpoint
    /// aggregates and the cluster-wide busy count coherent.  *Every*
    /// mutation of an instance owned by a cluster must flow through here
    /// (or through a Cluster method that does).
    pub fn mutate<R>(&mut self, id: InstanceId, f: impl FnOnce(&mut InstanceSim) -> R) -> R {
        let before = self.snapshot(id);
        let out = f(&mut self.instances[id]);
        self.apply_delta(id, before);
        out
    }

    /// Enqueue a request on an instance (aggregate-coherent).
    pub fn push_waiting(&mut self, id: InstanceId, req: Request) {
        self.mutate(id, |inst| inst.push_waiting(req));
    }

    /// Drain an instance's waiting queue (aggregate-coherent).
    pub fn take_waiting(&mut self, id: InstanceId) -> Vec<Request> {
        self.mutate(id, |inst| inst.take_waiting())
    }

    /// Order the waiting queue, admit while memory lasts, and plan the
    /// next decode chunk — the engine's per-chunk hot path, fused into
    /// one aggregate-coherent call that borrows the perf profile instead
    /// of cloning it.
    pub fn plan_next_chunk(
        &mut self,
        id: InstanceId,
        now: Time,
        policy: &SchedPolicy,
    ) -> Option<ChunkPlan> {
        let before = self.snapshot(id);
        let plan = {
            let Cluster { instances, perf, .. } = self;
            let inst = &mut instances[id];
            // Scheduler policy orders the waiting queue (§6.5).
            // Head-only ordering keeps overload queues O(n) to manage.
            policy.order_head(&mut inst.waiting, now, 128);
            let profile = perf.profile(inst.model, inst.gpu);
            // Per-chunk prefill budget ≈ 0.5 s of prompt throughput:
            // bounds the TTFT impact of bulk admissions (NIW chunking,
            // §6.2).  Decode-phase instances receive already-prefilled
            // work, so no prompt-compute budget gates their admissions.
            let prefill_budget = match inst.phase {
                Phase::Decode => u64::MAX,
                _ => (profile.prompt_tps * 0.5) as u64,
            };
            let admitted = if inst.state == InstState::Active {
                inst.admit(now, prefill_budget, profile.max_batch)
            } else {
                Vec::new()
            };
            inst.plan_chunk(now, admitted, profile)
        };
        self.apply_delta(id, before);
        plan
    }

    /// Active (serving) instance ids for an endpoint.
    pub fn active_instances(&self, model: ModelKind, region: Region) -> Vec<InstanceId> {
        self.endpoints
            .get(&(model, region))
            .map(|e| {
                e.instances
                    .iter()
                    .copied()
                    .filter(|&i| self.instances[i].state == InstState::Active)
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Allocated instance count (provisioning + active + draining) — what
    /// the instance-hour ledgers integrate.
    pub fn allocated_count(&self, model: ModelKind, region: Region) -> usize {
        self.endpoints.get(&(model, region)).map(|e| e.instances.len()).unwrap_or(0)
    }

    /// Allocated instance counts split by GPU SKU (the controller's
    /// per-SKU n_{j,k}) — O(1) from the roster-maintained array.
    pub fn allocated_by_gpu(&self, model: ModelKind, region: Region) -> [usize; GpuKind::COUNT] {
        self.endpoints
            .get(&(model, region))
            .map(|e| e.alloc_by_gpu)
            .unwrap_or([0; GpuKind::COUNT])
    }

    /// *Active* instances of one SKU at an endpoint, summed across
    /// pools — the O(1) signal SKU-aware region routing reads ("does
    /// this region have the preferred SKU serving right now?").
    pub fn active_count_by_gpu(&self, model: ModelKind, region: Region, gpu: GpuKind) -> usize {
        self.endpoints
            .get(&(model, region))
            .map(|e| e.agg.iter().map(|a| a.count_by_gpu[gpu.index()]).sum())
            .unwrap_or(0)
    }

    /// Does one SKU at an endpoint still have KV headroom?  True when
    /// the SKU's active instances exist and their summed reserved KV is
    /// under `frac` of their summed capacity — the O(1) endpoint-level
    /// approximation of the instance-level headroom test the affinity
    /// cascade applies (queued-but-unadmitted tokens are not split per
    /// SKU, so this reads reserved KV only).
    pub fn sku_has_headroom(
        &self,
        model: ModelKind,
        region: Region,
        gpu: GpuKind,
        frac: f64,
    ) -> bool {
        let Some(ep) = self.endpoints.get(&(model, region)) else {
            return false;
        };
        let mut used = 0u64;
        let mut cap = 0u64;
        for a in &ep.agg {
            used += a.kv_used_by_gpu[gpu.index()];
            cap += a.kv_capacity_by_gpu[gpu.index()];
        }
        cap > 0 && (used as f64) < frac * cap as f64
    }

    /// Effective memory utilization across active instances (§6.1) —
    /// O(1) from the incremental aggregates.
    pub fn effective_util(&self, model: ModelKind, region: Region) -> f64 {
        let t = self.endpoints[&(model, region)].totals();
        if t.kv_capacity == 0 {
            1.0 // no serving capacity ⇒ saturated for routing purposes
        } else {
            t.kv_used as f64 / t.kv_capacity as f64
        }
    }

    /// Effective utilization counting queued-but-unadmitted work too —
    /// the signal the Queue Manager drains against, so a release loop
    /// sees its own effect immediately (§6.2).  O(1).
    pub fn effective_util_with_waiting(&self, model: ModelKind, region: Region) -> f64 {
        let t = self.endpoints[&(model, region)].totals();
        if t.kv_capacity == 0 {
            1.0
        } else {
            (t.kv_used + t.waiting_tokens) as f64 / t.kv_capacity as f64
        }
    }

    /// Pool-scoped effective memory utilization (`None` ⇒ all pools) —
    /// the reactive/Siloed/Chiron scaling signal.  O(1).
    pub fn pool_util(&self, model: ModelKind, region: Region, pool: Option<PoolTag>) -> f64 {
        let ep = &self.endpoints[&(model, region)];
        let t = match pool {
            Some(p) => ep.agg[p.index()],
            None => ep.totals(),
        };
        if t.kv_capacity == 0 {
            1.0
        } else {
            t.kv_used as f64 / t.kv_capacity as f64
        }
    }

    /// Waiting + running tokens across an endpoint's active instances
    /// (backpressure signal).  O(1).
    pub fn pending_tokens(&self, model: ModelKind, region: Region) -> u64 {
        self.endpoints[&(model, region)].totals().pending_tokens
    }

    /// True when no instance anywhere holds queued or running work — the
    /// engine's per-event termination check, O(1) via the busy counter.
    pub fn is_all_idle(&self) -> bool {
        self.busy_instances == 0
    }

    /// Scale out one instance of the requested GPU SKU, choosing the
    /// fastest source (§6.4) — spot reclaim and redeploy stay within the
    /// SKU, since a VM's silicon is fixed even when weights are not.
    /// Returns `(instance id, ready time, previous model)`; the third
    /// element is the model the VM hosted before (== `model` for fresh
    /// VMs and same-model reclaims) so callers can re-record the *old*
    /// endpoint's spot ledgers after a cross-model reclaim.  Records
    /// provisioning waste.
    ///
    /// This is [`Cluster::reclaim_spot`] followed by
    /// [`Cluster::provision_fresh`]; callers that want to order the two
    /// sources differently across SKUs (the autoscaler's spot-first,
    /// most-valuable-SKU-first policy) call them directly.
    pub fn scale_out(
        &mut self,
        model: ModelKind,
        region: Region,
        pool: PoolTag,
        gpu: GpuKind,
        now: Time,
        metrics: &mut Metrics,
    ) -> Option<(InstanceId, Time, ModelKind)> {
        self.reclaim_spot(model, region, pool, gpu, now, metrics).or_else(|| {
            self.provision_fresh(model, region, pool, gpu, now, metrics)
                .map(|(id, ready)| (id, ready, model))
        })
    }

    /// Take one donated VM of the requested SKU back from the region's
    /// spot pool (§6.4 sources 1–2): same-model reclaim in ~1 min, or a
    /// cross-model VM with a ~10 min weights redeploy.  Returns
    /// `(instance id, ready time, previous model)` — callers must
    /// re-record the previous model's ledgers when it differs, or its
    /// spot ledger would keep accruing revenue for a VM that left the
    /// pool.  Returns `None` when the pool holds no VM of the SKU or
    /// the endpoint is at `max_instances`.
    pub fn reclaim_spot(
        &mut self,
        model: ModelKind,
        region: Region,
        pool: PoolTag,
        gpu: GpuKind,
        now: Time,
        metrics: &mut Metrics,
    ) -> Option<(InstanceId, Time, ModelKind)> {
        // A dark region refuses provisioning: `pool_util` reports 1.0 for
        // an endpoint with zero capacity, so without this gate the
        // reactive autoscaler would pour replacement VMs into the outage.
        if !self.region_available(region) {
            return None;
        }
        if self.allocated_count(model, region) >= self.params.max_instances {
            return None;
        }
        // 1. same-model spot instance (matching SKU) in this region.
        if let Some(pos) = {
            let spot = &self.spot_pool[&region];
            spot.iter()
                .position(|&i| self.instances[i].model == model && self.instances[i].gpu == gpu)
        } {
            let id = self.spot_pool.get_mut(&region).unwrap().remove(pos);
            let ready = now + self.params.spot_reclaim_secs;
            metrics.scaling_waste.record("spot-same-model", self.params.spot_reclaim_secs);
            self.reassign(id, model, region, pool, ready);
            return Some((id, ready, model));
        }
        // 2. cross-model spot instance of the SKU (weights redeploy).
        if let Some(pos) = {
            let spot = &self.spot_pool[&region];
            spot.iter()
                .position(|&i| self.instances[i].model != model && self.instances[i].gpu == gpu)
        } {
            let id = self.spot_pool.get_mut(&region).unwrap().remove(pos);
            let old_model = self.instances[id].model;
            let ready = now + self.params.local_redeploy_secs;
            metrics
                .scaling_waste
                .record("spot-cross-model", self.params.local_redeploy_secs);
            // Remove from the old endpoint's roster if still listed.
            self.roster_remove(old_model, region, id);
            self.reassign(id, model, region, pool, ready);
            return Some((id, ready, old_model));
        }
        None
    }

    /// Provision a fresh VM of the requested SKU from the regional
    /// budget (§6.4 source 3): ~10 min when the weights are in the
    /// region's repository, 2 h otherwise.  Returns `None` when the
    /// budget is exhausted or the endpoint is at `max_instances`.
    pub fn provision_fresh(
        &mut self,
        model: ModelKind,
        region: Region,
        pool: PoolTag,
        gpu: GpuKind,
        now: Time,
        metrics: &mut Metrics,
    ) -> Option<(InstanceId, Time)> {
        if !self.region_available(region) {
            return None;
        }
        if self.allocated_count(model, region) >= self.params.max_instances {
            return None;
        }
        if self.vm_budget[region.index()][gpu.index()] > 0 {
            self.vm_budget[region.index()][gpu.index()] -= 1;
            let local = self.local_weights[&region].contains(&model);
            let delay = if local {
                self.params.local_redeploy_secs
            } else {
                self.params.remote_redeploy_secs
            };
            metrics.scaling_waste.record(
                if local { "vm-local-deploy" } else { "vm-remote-deploy" },
                delay,
            );
            let id = self.spawn_instance(model, region, pool, gpu, InstState::Provisioning {
                until: now + delay,
            });
            return Some((id, now + delay));
        }
        None
    }

    fn reassign(&mut self, id: InstanceId, model: ModelKind, region: Region, pool: PoolTag, ready: Time) {
        let kv_cap = self.perf.profile(model, self.instances[id].gpu).serving_kv_budget();
        // The instance comes from the spot pool (inactive, empty), so the
        // aggregate delta is a no-op — but route it through `mutate` so
        // the invariant holds by construction.
        self.mutate(id, |inst| {
            debug_assert!(inst.batch.is_empty() && inst.waiting.is_empty());
            inst.model = model;
            inst.pool = pool;
            inst.kv_capacity = kv_cap;
            inst.kv_used = 0;
            inst.state = InstState::Provisioning { until: ready };
        });
        self.roster_add(model, region, pool, id);
    }

    /// Scale in: drain the least-loaded active instance in a pool,
    /// optionally restricted to one GPU SKU (the heterogeneity-aware
    /// paths drain most-expensive-first).  The instance converts to spot
    /// once its batch empties (engine calls [`Cluster::finish_drain`]).
    /// Returns the drained instance id.
    pub fn scale_in(
        &mut self,
        model: ModelKind,
        region: Region,
        pool_filter: Option<PoolTag>,
        gpu_filter: Option<GpuKind>,
    ) -> Option<InstanceId> {
        let ep = self.endpoints.get(&(model, region))?;
        // Keep the robustness floor (min_instances) per endpoint, and at
        // least one active instance per pool (a siloed NIW pool must not
        // drain to zero and strand its tier).  Counts come from the
        // aggregates — O(1) instead of an instance scan.
        let active_total = ep.totals().count;
        if active_total <= self.params.min_instances {
            return None;
        }
        if let Some(p) = pool_filter {
            // Pool-scoped scale-in (Siloed/Chiron): the robustness floor
            // applies per pool — §4's Fig 8 observation that Siloed holds
            // 2 IW + 2 NIW instances where Unified shares 2.
            if ep.agg[p.index()].count <= self.params.min_instances {
                return None;
            }
        }
        // Least-loaded eligible instance (first minimum, like min_by_key).
        let mut best: Option<(u64, InstanceId)> = None;
        for &i in &ep.instances {
            let inst = &self.instances[i];
            if inst.state != InstState::Active {
                continue;
            }
            if pool_filter.map_or(false, |p| inst.pool != p) {
                continue;
            }
            if gpu_filter.map_or(false, |g| inst.gpu != g) {
                continue;
            }
            let key = inst.pending_tokens();
            match best {
                Some((bk, _)) if bk <= key => {}
                _ => best = Some((key, i)),
            }
        }
        let (_, id) = best?;
        self.mutate(id, |inst| inst.state = InstState::Draining);
        Some(id)
    }

    /// Move a fully drained instance to the spot pool.
    pub fn finish_drain(&mut self, id: InstanceId) {
        // Draining → Spot is inactive on both sides: no aggregate delta,
        // but keep the funnel for the busy/consistency invariants.
        self.mutate(id, |inst| {
            debug_assert!(inst.batch.is_empty());
            inst.state = InstState::Spot;
            inst.kv_used = 0;
        });
        let (model, region) = {
            let inst = &self.instances[id];
            (inst.model, inst.region)
        };
        self.roster_remove(model, region, id);
        self.spot_pool.get_mut(&region).unwrap().push(id);
    }

    /// Instances currently donated to spot, per region.
    pub fn spot_count(&self, region: Region) -> usize {
        self.spot_pool[&region].len()
    }

    // ── Fault plane ────────────────────────────────────────────────────
    //
    // The availability mask and the crash/preemption paths below are only
    // exercised when a non-empty `FaultPlan` schedules fault events; in a
    // fault-free run the mask stays all-clear and no instance ever enters
    // `InstState::Dead`, so existing runs are bit-identical.

    /// Mark a region dark (inside an outage window) or lift the mark.
    /// Dark regions are excluded from routing and refuse provisioning.
    pub fn set_region_dark(&mut self, region: Region, dark: bool) {
        self.dark[region.index()] = dark;
    }

    /// True when the region is *not* dark — routable and provisionable.
    pub fn region_available(&self, region: Region) -> bool {
        !self.dark[region.index()]
    }

    /// True while any region is inside an outage window — the queue
    /// manager's graceful-degradation signal (defer NIW releases, shed
    /// over-capacity NIW backlog before any interactive request suffers).
    pub fn any_region_dark(&self) -> bool {
        self.dark.iter().any(|&d| d)
    }

    /// Open a latency-degradation window: the region stays routable but
    /// every request it serves is charged `extra` seconds, and retry
    /// failover prefers clean regions.
    pub fn set_region_degraded(&mut self, region: Region, extra: Time) {
        self.degraded[region.index()] = true;
        self.extra_latency[region.index()] = extra;
    }

    /// Close a latency-degradation window.
    pub fn clear_region_degraded(&mut self, region: Region) {
        self.degraded[region.index()] = false;
        self.extra_latency[region.index()] = 0.0;
    }

    /// True while the region is inside a degradation window.
    pub fn region_degraded(&self, region: Region) -> bool {
        self.degraded[region.index()]
    }

    /// Extra latency (seconds) currently charged to requests served by
    /// this region — 0.0 outside degradation windows.
    pub fn latency_penalty(&self, region: Region) -> f64 {
        self.extra_latency[region.index()]
    }

    /// Kill a roster instance (outage or VM-crash hazard): splits its
    /// batch into finished-this-chunk vs killed work, zeroes its load,
    /// removes it from the roster, and returns its budget slot so the
    /// autoscaler can provision a replacement once the region is live.
    /// The arena slot stays (`InstState::Dead`) so stale `ChunkDone` /
    /// `ProvisionDone` events resolve harmlessly.
    pub fn crash_instance(&mut self, id: InstanceId, now: Time) -> CrashedWork {
        let work = self.mutate(id, |inst| inst.crash(now));
        let (model, region, gpu) = {
            let inst = &self.instances[id];
            (inst.model, inst.region, inst.gpu)
        };
        self.roster_remove(model, region, id);
        self.vm_budget[region.index()][gpu.index()] += 1;
        work
    }

    /// Spot-market preemption shock: the market reclaims `count` donated
    /// VMs from the back of a region's spot pool (most recently donated
    /// first — deterministic).  Preempted VMs are gone for good: they go
    /// `Dead` and do *not* return a budget slot, shrinking the fast
    /// spot-reclaim path the autoscaler leans on.  Returns the number
    /// actually preempted (the pool may be smaller than `count`).
    pub fn preempt_spot(&mut self, region: Region, count: usize) -> usize {
        let mut taken = 0;
        while taken < count {
            let Some(id) = self.spot_pool.get_mut(&region).unwrap().pop() else {
                break;
            };
            self.mutate(id, |inst| inst.state = InstState::Dead);
            taken += 1;
        }
        taken
    }

    /// Recompute every aggregate, roster cache and cached token counter
    /// from scratch and compare with the incrementally-maintained values.
    /// Test/debug support for the incremental-accounting refactor.
    pub fn aggregates_consistent(&self) -> bool {
        let mut ok = true;
        for (_, ep) in self.endpoints.iter() {
            let mut agg = [PoolAgg::default(); 6];
            let mut alloc_by_gpu = [0usize; GpuKind::COUNT];
            for &i in &ep.instances {
                let inst = &self.instances[i];
                let (waiting, running) = inst.recount_tokens();
                // Cached per-instance counters match the raw queues.
                ok &= waiting == inst.waiting_tokens();
                ok &= waiting + running == inst.pending_tokens();
                alloc_by_gpu[inst.gpu.index()] += 1;
                if inst.state == InstState::Active {
                    let a = &mut agg[inst.pool.index()];
                    a.kv_used += inst.kv_used;
                    a.kv_capacity += inst.kv_capacity;
                    a.waiting_tokens += waiting;
                    a.pending_tokens += waiting + running;
                    a.count += 1;
                    a.count_by_gpu[inst.gpu.index()] += 1;
                    a.kv_used_by_gpu[inst.gpu.index()] += inst.kv_used;
                    a.kv_capacity_by_gpu[inst.gpu.index()] += inst.kv_capacity;
                }
                // Roster caches agree with pool eligibility.
                ok &= ep.iw_instances.contains(&i) == inst.pool.serves_iw();
                ok &= ep.niw_instances.contains(&i) == inst.pool.serves_niw();
                // Phase rosters agree with each instance's phase tag
                // (both empty on unified fleets).
                ok &= ep.prefill_instances.contains(&i) == (inst.phase == Phase::Prefill);
                ok &= ep.decode_instances.contains(&i) == (inst.phase == Phase::Decode);
            }
            ok &= agg == ep.agg;
            ok &= alloc_by_gpu == ep.alloc_by_gpu;
        }
        let busy = self
            .instances
            .iter()
            .filter(|i| !i.batch.is_empty() || !i.waiting.is_empty())
            .count();
        ok && busy == self.busy_instances
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuKind;

    fn cluster() -> Cluster {
        Cluster::new(
            &ModelKind::EVAL4,
            PerfTable::new(GpuKind::A100x8, &ModelKind::EVAL4),
            ScalingParams::default(),
            &[(PoolTag::Unified, 3)],
            10,
        )
    }

    #[test]
    fn initial_layout() {
        let c = cluster();
        assert_eq!(c.instances.len(), 4 * 3 * 3);
        for &m in &ModelKind::EVAL4 {
            for r in Region::ALL {
                assert_eq!(c.active_instances(m, r).len(), 3);
            }
        }
        assert!(c.aggregates_consistent());
        assert!(c.is_all_idle());
    }

    #[test]
    fn endpoint_index_walk_matches_keys() {
        let c = cluster();
        assert_eq!(c.endpoints.len(), 12);
        for (i, &k) in c.endpoints.keys().enumerate() {
            assert_eq!(c.endpoints.key_at(i), k);
        }
    }

    #[test]
    fn scale_in_then_out_uses_spot_fast_path() {
        let mut c = cluster();
        let mut metrics = Metrics::default();
        let id = c.scale_in(ModelKind::Llama2_70B, Region::EastUs, None, None).unwrap();
        c.finish_drain(id);
        assert_eq!(c.spot_count(Region::EastUs), 1);
        let (id2, ready, prev) = c
            .scale_out(ModelKind::Llama2_70B, Region::EastUs, PoolTag::Unified,
                       GpuKind::A100x8, 100.0, &mut metrics)
            .unwrap();
        assert_eq!(id, id2);
        assert_eq!(prev, ModelKind::Llama2_70B); // same-model reclaim
        assert!((ready - 160.0).abs() < 1e-9); // 1 min spot reclaim
        assert_eq!(c.spot_count(Region::EastUs), 0);
        assert!(c.aggregates_consistent());
    }

    #[test]
    fn cross_model_spot_costs_redeploy() {
        let mut c = cluster();
        let mut metrics = Metrics::default();
        let id = c.scale_in(ModelKind::Bloom176B, Region::WestUs, None, None).unwrap();
        c.finish_drain(id);
        let (id2, ready, prev) = c
            .scale_out(ModelKind::Llama2_70B, Region::WestUs, PoolTag::Unified,
                       GpuKind::A100x8, 0.0, &mut metrics)
            .unwrap();
        assert_eq!(id, id2);
        // The caller learns whose spot ledger to re-record.
        assert_eq!(prev, ModelKind::Bloom176B);
        assert!((ready - 600.0).abs() < 1e-9); // 10 min redeploy
        assert_eq!(c.instances[id2].model, ModelKind::Llama2_70B);
        // KV capacity switched to the new model's profile.
        assert_eq!(
            c.instances[id2].kv_capacity,
            c.perf.profile(ModelKind::Llama2_70B, GpuKind::A100x8).serving_kv_budget()
        );
        assert!(c.aggregates_consistent());
    }

    #[test]
    fn fresh_vm_consumes_budget() {
        let mut c = cluster();
        let mut metrics = Metrics::default();
        let gpu = GpuKind::A100x8;
        let before = c.vm_budget[Region::EastUs.index()][gpu.index()];
        let (_id, ready, _) = c
            .scale_out(ModelKind::Llama31_8B, Region::EastUs, PoolTag::Unified, gpu, 0.0, &mut metrics)
            .unwrap();
        assert_eq!(c.vm_budget[Region::EastUs.index()][gpu.index()], before - 1);
        assert!((ready - 600.0).abs() < 1e-9);
    }

    #[test]
    fn remote_weights_cost_2h() {
        let mut c = cluster();
        c.local_weights.get_mut(&Region::WestUs).unwrap().retain(|&m| m != ModelKind::Bloom176B);
        let mut metrics = Metrics::default();
        let (_, ready, _) = c
            .scale_out(ModelKind::Bloom176B, Region::WestUs, PoolTag::Unified,
                       GpuKind::A100x8, 0.0, &mut metrics)
            .unwrap();
        assert!((ready - 7200.0).abs() < 1e-9);
    }

    #[test]
    fn min_instances_floor_respected() {
        let mut c = cluster();
        // 3 active; min is 2 ⇒ only one scale-in allowed.
        assert!(c.scale_in(ModelKind::Llama2_70B, Region::EastUs, None, None).is_some());
        assert!(c.scale_in(ModelKind::Llama2_70B, Region::EastUs, None, None).is_none());
    }

    #[test]
    fn max_instances_cap_respected() {
        let mut c = cluster();
        let mut metrics = Metrics::default();
        let mut added = 0;
        while c
            .scale_out(ModelKind::Llama32_3B, Region::CentralUs, PoolTag::Unified,
                       GpuKind::A100x8, 0.0, &mut metrics)
            .is_some()
        {
            added += 1;
            assert!(added < 100, "runaway scale-out");
        }
        // 3 initial + 10 regional VM budget = 13, still under the
        // max_instances cap of 20 — the budget binds first here.
        let got = c.allocated_count(ModelKind::Llama32_3B, Region::CentralUs);
        assert_eq!(got, 13);
        assert!(got <= c.params.max_instances);
    }

    #[test]
    fn no_capacity_reports_saturated_util() {
        let mut c = cluster();
        let ids = c.endpoints[&(ModelKind::Bloom176B, Region::WestUs)].instances.clone();
        for id in ids {
            c.mutate(id, |inst| inst.state = InstState::Draining);
        }
        assert_eq!(c.effective_util(ModelKind::Bloom176B, Region::WestUs), 1.0);
        assert!(c.aggregates_consistent());
    }

    fn mixed_cluster() -> Cluster {
        let fleet = FleetSpec::mixed(&[(GpuKind::H100x8, 0.5), (GpuKind::A100x8, 0.5)]);
        Cluster::new_fleet(
            &[ModelKind::Llama2_70B],
            PerfTable::for_fleet(&[GpuKind::H100x8, GpuKind::A100x8], &[ModelKind::Llama2_70B]),
            ScalingParams::default(),
            &[(PoolTag::Unified, 4)],
            5,
            &fleet,
        )
    }

    #[test]
    fn mixed_fleet_initial_split_and_accounting() {
        let c = mixed_cluster();
        for r in Region::ALL {
            let by_gpu = c.allocated_by_gpu(ModelKind::Llama2_70B, r);
            assert_eq!(by_gpu[GpuKind::H100x8.index()], 2);
            assert_eq!(by_gpu[GpuKind::A100x8.index()], 2);
            // The per-region VM budget splits across SKUs by fleet
            // weight (largest remainder: 5 → 3 + 2; no MI300 in this
            // fleet), keeping total resources equal to a homogeneous
            // fleet's.
            assert_eq!(c.vm_budget[r.index()], [3, 2, 0]);
        }
        assert!(c.instances.iter().any(|i| i.gpu == GpuKind::H100x8));
        assert!(c.instances.iter().any(|i| i.gpu == GpuKind::A100x8));
        assert!(c.aggregates_consistent());
    }

    #[test]
    fn scale_paths_are_sku_scoped() {
        let mut c = mixed_cluster();
        let mut metrics = Metrics::default();
        let (m, r) = (ModelKind::Llama2_70B, Region::EastUs);
        // Drain one H100 into the spot pool.
        let id = c.scale_in(m, r, None, Some(GpuKind::H100x8)).unwrap();
        assert_eq!(c.instances[id].gpu, GpuKind::H100x8);
        c.finish_drain(id);
        assert_eq!(c.spot_count(r), 1);
        assert_eq!(c.allocated_by_gpu(m, r)[GpuKind::H100x8.index()], 1);
        // Scaling out an A100 must NOT reclaim the H100 spot VM: it
        // provisions a fresh A100 (10 min), leaving the spot pool alone.
        let (a_id, ready, _) = c
            .scale_out(m, r, PoolTag::Unified, GpuKind::A100x8, 0.0, &mut metrics)
            .unwrap();
        assert_eq!(c.instances[a_id].gpu, GpuKind::A100x8);
        assert!((ready - 600.0).abs() < 1e-9);
        assert_eq!(c.spot_count(r), 1);
        // Scaling out an H100 reclaims the same-SKU spot VM in 1 min.
        let (h_id, ready, _) = c
            .scale_out(m, r, PoolTag::Unified, GpuKind::H100x8, 0.0, &mut metrics)
            .unwrap();
        assert_eq!(h_id, id);
        assert!((ready - 60.0).abs() < 1e-9);
        assert_eq!(c.spot_count(r), 0);
        assert!(c.aggregates_consistent());
    }

    fn three_way_cluster() -> Cluster {
        let fleet = FleetSpec::mixed_3way();
        Cluster::new_fleet(
            &[ModelKind::Llama2_70B],
            PerfTable::for_fleet(&GpuKind::ALL, &[ModelKind::Llama2_70B]),
            ScalingParams::default(),
            &[(PoolTag::Unified, 6)],
            6,
            &fleet,
        )
    }

    #[test]
    fn precomputed_sku_orders_match_price_sheets() {
        let c = three_way_cluster();
        // α ascending: A100 < MI300 < H100.
        assert_eq!(
            c.gpus_cost_asc,
            vec![GpuKind::A100x8, GpuKind::Mi300x8, GpuKind::H100x8]
        );
        assert_eq!(
            c.gpus_cost_desc,
            vec![GpuKind::H100x8, GpuKind::Mi300x8, GpuKind::A100x8]
        );
        // Spot value descending: H100 > MI300 > A100.
        assert_eq!(
            c.gpus_spot_desc,
            vec![GpuKind::H100x8, GpuKind::Mi300x8, GpuKind::A100x8]
        );
        // HBM descending: MI300 first; the 640 GiB tie keeps fleet order.
        assert_eq!(
            c.gpus_hbm_desc,
            vec![GpuKind::Mi300x8, GpuKind::H100x8, GpuKind::A100x8]
        );
    }

    #[test]
    fn three_way_fleet_splits_and_accounts() {
        let c = three_way_cluster();
        assert!(c.hbm_diverse);
        for r in Region::ALL {
            let by_gpu = c.allocated_by_gpu(ModelKind::Llama2_70B, r);
            assert_eq!(by_gpu, [2, 2, 2]);
            assert_eq!(c.vm_budget[r.index()], [2, 2, 2]);
            for g in GpuKind::ALL {
                assert_eq!(c.active_count_by_gpu(ModelKind::Llama2_70B, r, g), 2);
            }
        }
        assert!(c.aggregates_consistent());
    }

    #[test]
    fn sku_headroom_tracks_per_sku_kv() {
        let mut c = three_way_cluster();
        let (m, r) = (ModelKind::Llama2_70B, Region::EastUs);
        // Idle instances: every SKU has headroom.
        for g in GpuKind::ALL {
            assert!(c.sku_has_headroom(m, r, g, 0.70), "{g}");
        }
        // Fill only the MI300s past the fraction: MI300 loses headroom,
        // the other SKUs keep it (per-SKU aggregates, not the endpoint
        // total, drive the signal).
        let ids = c.endpoints[&(m, r)].instances.clone();
        for id in ids {
            if c.instances[id].gpu == GpuKind::Mi300x8 {
                c.mutate(id, |inst| {
                    inst.kv_used = (inst.kv_capacity as f64 * 0.9) as u64;
                });
            }
        }
        assert!(!c.sku_has_headroom(m, r, GpuKind::Mi300x8, 0.70));
        assert!(c.sku_has_headroom(m, r, GpuKind::H100x8, 0.70));
        assert!(c.sku_has_headroom(m, r, GpuKind::A100x8, 0.70));
        // No active instance of a SKU ⇒ no headroom (capacity 0).
        let ids = c.endpoints[&(m, r)].instances.clone();
        for id in ids {
            if c.instances[id].gpu == GpuKind::H100x8 {
                c.mutate(id, |inst| inst.state = InstState::Draining);
            }
        }
        assert!(!c.sku_has_headroom(m, r, GpuKind::H100x8, 0.70));
        assert!(c.aggregates_consistent());
    }

    #[test]
    fn reclaim_spot_and_provision_fresh_are_disjoint_sources() {
        let mut c = three_way_cluster();
        let mut metrics = Metrics::default();
        let (m, r) = (ModelKind::Llama2_70B, Region::EastUs);
        // Nothing donated yet: reclaim fails, fresh provisioning works.
        assert!(c.reclaim_spot(m, r, PoolTag::Unified, GpuKind::Mi300x8, 0.0, &mut metrics)
            .is_none());
        let (id, ready) = c
            .provision_fresh(m, r, PoolTag::Unified, GpuKind::Mi300x8, 0.0, &mut metrics)
            .unwrap();
        assert_eq!(c.instances[id].gpu, GpuKind::Mi300x8);
        assert!((ready - 600.0).abs() < 1e-9);
        // Donate an MI300, then reclaim it same-model in 1 min.
        let drained = c.scale_in(m, r, None, Some(GpuKind::Mi300x8)).unwrap();
        c.finish_drain(drained);
        let (id2, ready2, prev) = c
            .reclaim_spot(m, r, PoolTag::Unified, GpuKind::Mi300x8, 100.0, &mut metrics)
            .unwrap();
        assert_eq!(id2, drained);
        assert_eq!(prev, m);
        assert!((ready2 - 160.0).abs() < 1e-9);
        assert!(c.aggregates_consistent());
    }

    #[test]
    fn crash_instance_frees_roster_slot_and_returns_budget() {
        use crate::config::Tier;
        use crate::trace::types::AppKind;
        let mut c = cluster();
        let (m, r) = (ModelKind::Llama2_70B, Region::EastUs);
        let id = c.endpoints[&(m, r)].instances[0];
        c.push_waiting(id, Request {
            id: 1,
            arrival: 0.0,
            model: m,
            origin: r,
            tier: Tier::IwF,
            app: AppKind::Chat,
            input_tokens: 100,
            output_tokens: 10,
        });
        let budget_before = c.vm_budget[r.index()][GpuKind::A100x8.index()];
        let work = c.crash_instance(id, 5.0);
        assert_eq!(work.killed.len(), 1);
        assert!(work.finished.is_empty());
        assert_eq!(c.instances[id].state, InstState::Dead);
        assert!(!c.endpoints[&(m, r)].instances.contains(&id));
        assert_eq!(c.vm_budget[r.index()][GpuKind::A100x8.index()], budget_before + 1);
        assert_eq!(c.active_instances(m, r).len(), 2);
        assert!(c.is_all_idle());
        assert!(c.aggregates_consistent());
    }

    #[test]
    fn preempt_spot_kills_donated_vms_without_budget_return() {
        let mut c = cluster();
        let r = Region::EastUs;
        let a = c.scale_in(ModelKind::Llama2_70B, r, None, None).unwrap();
        c.finish_drain(a);
        let b = c.scale_in(ModelKind::Bloom176B, r, None, None).unwrap();
        c.finish_drain(b);
        assert_eq!(c.spot_count(r), 2);
        let budget = c.vm_budget[r.index()];
        // Ask for more than the pool holds: both go, count reports 2.
        assert_eq!(c.preempt_spot(r, 5), 2);
        assert_eq!(c.spot_count(r), 0);
        assert_eq!(c.instances[a].state, InstState::Dead);
        assert_eq!(c.instances[b].state, InstState::Dead);
        assert_eq!(c.vm_budget[r.index()], budget); // no slot returned
        assert!(c.aggregates_consistent());
    }

    #[test]
    fn dark_region_refuses_both_provisioning_sources() {
        let mut c = cluster();
        let mut metrics = Metrics::default();
        let (m, r) = (ModelKind::Llama2_70B, Region::CentralUs);
        // Seed the spot pool so reclaim would otherwise succeed.
        let id = c.scale_in(m, r, None, None).unwrap();
        c.finish_drain(id);
        c.set_region_dark(r, true);
        assert!(!c.region_available(r));
        assert!(c.any_region_dark());
        assert!(c.scale_out(m, r, PoolTag::Unified, GpuKind::A100x8, 0.0, &mut metrics).is_none());
        // Lifting the mark restores both sources.
        c.set_region_dark(r, false);
        assert!(c.scale_out(m, r, PoolTag::Unified, GpuKind::A100x8, 0.0, &mut metrics).is_some());
        assert!(!c.any_region_dark());
    }

    #[test]
    fn degradation_mask_tracks_penalty() {
        let mut c = cluster();
        let r = Region::WestUs;
        assert!(!c.region_degraded(r));
        assert_eq!(c.latency_penalty(r), 0.0);
        c.set_region_degraded(r, 0.25);
        assert!(c.region_degraded(r));
        assert_eq!(c.latency_penalty(r), 0.25);
        c.clear_region_degraded(r);
        assert!(!c.region_degraded(r));
        assert_eq!(c.latency_penalty(r), 0.0);
    }

    #[test]
    fn set_disagg_partitions_rosters_and_scaling_keeps_the_split() {
        let mut c = cluster();
        let mut metrics = Metrics::default();
        c.set_disagg(crate::config::DisaggParams::enabled());
        let (m, r) = (ModelKind::Llama2_70B, Region::EastUs);
        // 3 instances, fraction 0.35 ⇒ ceil(1.05) = 2 prefill, 1 decode.
        let ep = &c.endpoints[&(m, r)];
        assert_eq!(ep.prefill_instances.len(), 2);
        assert_eq!(ep.decode_instances.len(), 1);
        for &i in &ep.prefill_instances {
            assert_eq!(c.instances[i].phase, Phase::Prefill);
        }
        for &i in &ep.decode_instances {
            assert_eq!(c.instances[i].phase, Phase::Decode);
        }
        assert!(c.aggregates_consistent());
        // Scale-out keeps the split tracking the fraction: the 4th
        // instance joins decode (want = ceil(0.35·4) = 2 ≤ prefill's 2).
        let (id, _, _) = c
            .scale_out(m, r, PoolTag::Unified, GpuKind::A100x8, 0.0, &mut metrics)
            .unwrap();
        assert_eq!(c.instances[id].phase, Phase::Decode);
        // Drain + donate a prefill VM, then reclaim it: the phase is
        // re-assigned from the endpoint's balance, not remembered.
        let pid = c.endpoints[&(m, r)].prefill_instances[0];
        c.mutate(pid, |inst| inst.state = InstState::Draining);
        c.finish_drain(pid);
        assert!(!c.endpoints[&(m, r)].prefill_instances.contains(&pid));
        let (rid, _, _) = c
            .scale_out(m, r, PoolTag::Unified, GpuKind::A100x8, 0.0, &mut metrics)
            .unwrap();
        assert_eq!(rid, pid);
        // 3 rostered before the reclaim, 1 of them prefill ⇒ want =
        // ceil(0.35·4) = 2 > 1 ⇒ prefill again.
        assert_eq!(c.instances[rid].phase, Phase::Prefill);
        assert!(c.aggregates_consistent());
        // Per-phase SKU counts stay coherent with the rosters.
        let pre = c.phase_alloc_by_gpu(m, r, Phase::Prefill);
        let dec = c.phase_alloc_by_gpu(m, r, Phase::Decode);
        let total: usize = pre.iter().chain(dec.iter()).sum();
        assert_eq!(total, c.allocated_count(m, r));
    }

    #[test]
    fn unified_cluster_keeps_phase_rosters_empty() {
        let mut c = cluster();
        let mut metrics = Metrics::default();
        let (m, r) = (ModelKind::Llama2_70B, Region::EastUs);
        let id = c.scale_in(m, r, None, None).unwrap();
        c.finish_drain(id);
        c.scale_out(m, r, PoolTag::Unified, GpuKind::A100x8, 0.0, &mut metrics).unwrap();
        for (_, ep) in c.endpoints.iter() {
            assert!(ep.prefill_instances.is_empty());
            assert!(ep.decode_instances.is_empty());
        }
        assert!(c.instances.iter().all(|i| i.phase == Phase::Unified));
        assert!(c.aggregates_consistent());
    }

    #[test]
    fn aggregates_track_load_and_state_changes() {
        use crate::config::Tier;
        use crate::trace::types::AppKind;
        let mut c = cluster();
        let id = c.endpoints[&(ModelKind::Llama2_70B, Region::EastUs)].instances[0];
        c.push_waiting(id, Request {
            id: 1,
            arrival: 0.0,
            model: ModelKind::Llama2_70B,
            origin: Region::EastUs,
            tier: Tier::IwF,
            app: AppKind::Chat,
            input_tokens: 1000,
            output_tokens: 200,
        });
        assert!(!c.is_all_idle());
        assert!(c.aggregates_consistent());
        let ep = &c.endpoints[&(ModelKind::Llama2_70B, Region::EastUs)];
        assert_eq!(ep.totals().waiting_tokens, 1200);
        assert_eq!(ep.totals().pending_tokens, 1200);

        // Admission + chunk planning moves waiting → kv_used/running.
        let plan = c.plan_next_chunk(id, 0.0, &SchedPolicy::Fcfs);
        assert!(plan.is_some());
        assert!(c.aggregates_consistent());
        let ep = &c.endpoints[&(ModelKind::Llama2_70B, Region::EastUs)];
        assert_eq!(ep.totals().waiting_tokens, 0);
        assert_eq!(ep.totals().kv_used, 1200);

        // Draining the instance removes its contribution entirely.
        c.mutate(id, |inst| inst.state = InstState::Draining);
        assert!(c.aggregates_consistent());
        let ep = &c.endpoints[&(ModelKind::Llama2_70B, Region::EastUs)];
        assert_eq!(ep.totals().kv_used, 0);
        assert_eq!(ep.totals().count, 2);
    }
}
