//! Cluster substrate: regions, model endpoints, VM budgets, the spot pool
//! and provisioning delays (§2.3).
//!
//! Scale-out sources, fastest first (§6.4):
//! 1. a spot instance already hosting the same model type (≈1 min),
//! 2. a spot instance of another model type — weights must be redeployed
//!    (≈10 min local),
//! 3. a fresh VM from the regional budget (≈10 min local; 2 h if the
//!    weights are not in the region's repository).
//!
//! Scale-in drains the least-loaded instance and donates it to the spot
//! pool (§2.3: a lost-opportunity sink that SageServe tries to shrink).

use std::collections::BTreeMap;

use crate::config::{ModelKind, Region, ScalingParams, Time};
use crate::metrics::Metrics;
use crate::perf::PerfTable;
use crate::sim::instance::{InstState, InstanceSim};

pub type InstanceId = usize;

/// Which workload pool an instance belongs to.  `Unified` strategies use
/// one pool; the Siloed baseline splits IW/NIW (§4); Chiron uses its
/// interactive/mixed/batch trio [34].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum PoolTag {
    Unified,
    SiloIw,
    SiloNiw,
    ChironInteractive,
    ChironMixed,
    ChironBatch,
}

impl PoolTag {
    /// May this pool serve interactive requests?
    pub fn serves_iw(self) -> bool {
        !matches!(self, PoolTag::SiloNiw | PoolTag::ChironBatch)
    }

    /// May this pool serve non-interactive requests?
    pub fn serves_niw(self) -> bool {
        !matches!(self, PoolTag::SiloIw | PoolTag::ChironInteractive)
    }
}

/// Per-(model, region) endpoint bookkeeping.
#[derive(Debug, Default, Clone)]
pub struct Endpoint {
    /// Instances allocated to this endpoint (any state except Spot).
    pub instances: Vec<InstanceId>,
    /// Last reactive scaling event (cooldown enforcement).
    pub last_scale: Time,
    /// LT-U / LT-UA deferred target from the last control epoch.
    pub target: Option<usize>,
    /// Forecast max TPS for the current hour (LT-UA gap checks).
    pub forecast_tps: f64,
}

/// The multi-region cluster state.
pub struct Cluster {
    pub instances: Vec<InstanceSim>,
    pub endpoints: BTreeMap<(ModelKind, Region), Endpoint>,
    /// Donated instances per region (still hosting their last model).
    pub spot_pool: BTreeMap<Region, Vec<InstanceId>>,
    /// Remaining un-allocated VMs per region.
    pub vm_budget: [usize; 3],
    /// Models whose weights are present in each region's repository
    /// (missing ⇒ 2 h remote redeploy).
    pub local_weights: BTreeMap<Region, Vec<ModelKind>>,
    pub perf: PerfTable,
    pub params: ScalingParams,
}

impl Cluster {
    /// Build a cluster with `initial_per_endpoint` active instances per
    /// (model, region) pool tag, plus `vm_budget_per_region` spare VMs.
    pub fn new(
        models: &[ModelKind],
        perf: PerfTable,
        params: ScalingParams,
        pools: &[(PoolTag, usize)],
        vm_budget_per_region: usize,
    ) -> Self {
        let mut cluster = Cluster {
            instances: Vec::new(),
            endpoints: BTreeMap::new(),
            spot_pool: Region::ALL.iter().map(|&r| (r, Vec::new())).collect(),
            vm_budget: [vm_budget_per_region; 3],
            local_weights: Region::ALL.iter().map(|&r| (r, models.to_vec())).collect(),
            perf,
            params,
        };
        for &model in models {
            for region in Region::ALL {
                cluster.endpoints.insert((model, region), Endpoint::default());
                for &(pool, count) in pools {
                    for _ in 0..count {
                        cluster.spawn_instance(model, region, pool, InstState::Active);
                    }
                }
            }
        }
        cluster
    }

    fn spawn_instance(
        &mut self,
        model: ModelKind,
        region: Region,
        pool: PoolTag,
        state: InstState,
    ) -> InstanceId {
        let id = self.instances.len();
        let kv_cap = self.perf.profile(model).serving_kv_budget();
        self.instances
            .push(InstanceSim::new(id, model, region, pool, state, kv_cap));
        self.endpoints.get_mut(&(model, region)).unwrap().instances.push(id);
        id
    }

    /// Active (serving) instance ids for an endpoint.
    pub fn active_instances(&self, model: ModelKind, region: Region) -> Vec<InstanceId> {
        self.endpoints
            .get(&(model, region))
            .map(|e| {
                e.instances
                    .iter()
                    .copied()
                    .filter(|&i| self.instances[i].state == InstState::Active)
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Allocated instance count (provisioning + active + draining) — what
    /// the instance-hour ledgers integrate.
    pub fn allocated_count(&self, model: ModelKind, region: Region) -> usize {
        self.endpoints.get(&(model, region)).map(|e| e.instances.len()).unwrap_or(0)
    }

    /// Effective memory utilization across active instances (§6.1).
    pub fn effective_util(&self, model: ModelKind, region: Region) -> f64 {
        let mut used = 0u64;
        let mut cap = 0u64;
        for &i in &self.endpoints[&(model, region)].instances {
            let inst = &self.instances[i];
            if inst.state == InstState::Active {
                used += inst.kv_used;
                cap += inst.kv_capacity;
            }
        }
        if cap == 0 {
            1.0 // no serving capacity ⇒ saturated for routing purposes
        } else {
            used as f64 / cap as f64
        }
    }

    /// Effective utilization counting queued-but-unadmitted work too —
    /// the signal the Queue Manager drains against, so a release loop
    /// sees its own effect immediately (§6.2).
    pub fn effective_util_with_waiting(&self, model: ModelKind, region: Region) -> f64 {
        let mut used = 0u64;
        let mut cap = 0u64;
        for &i in &self.endpoints[&(model, region)].instances {
            let inst = &self.instances[i];
            if inst.state == InstState::Active {
                used += inst.kv_used;
                used += inst.waiting_tokens();
                cap += inst.kv_capacity;
            }
        }
        if cap == 0 {
            1.0
        } else {
            used as f64 / cap as f64
        }
    }

    /// Waiting + running tokens across an endpoint (backpressure signal).
    pub fn pending_tokens(&self, model: ModelKind, region: Region) -> u64 {
        self.endpoints[&(model, region)]
            .instances
            .iter()
            .map(|&i| self.instances[i].pending_tokens())
            .sum()
    }

    /// Scale out one instance, choosing the fastest source (§6.4).
    /// Returns `(instance id, ready time)`; records provisioning waste.
    pub fn scale_out(
        &mut self,
        model: ModelKind,
        region: Region,
        pool: PoolTag,
        now: Time,
        metrics: &mut Metrics,
    ) -> Option<(InstanceId, Time)> {
        if self.allocated_count(model, region) >= self.params.max_instances {
            return None;
        }
        // 1. same-model spot instance in this region.
        let spot = self.spot_pool.get_mut(&region).unwrap();
        if let Some(pos) = spot.iter().position(|&i| self.instances[i].model == model) {
            let id = spot.remove(pos);
            let ready = now + self.params.spot_reclaim_secs;
            metrics.scaling_waste.record("spot-same-model", self.params.spot_reclaim_secs);
            self.reassign(id, model, region, pool, ready);
            return Some((id, ready));
        }
        // 2. cross-model spot instance (weights redeploy).
        if let Some(pos) = {
            let spot = &self.spot_pool[&region];
            spot.iter().position(|&i| self.instances[i].model != model)
        } {
            let id = self.spot_pool.get_mut(&region).unwrap().remove(pos);
            let old_model = self.instances[id].model;
            let ready = now + self.params.local_redeploy_secs;
            metrics
                .scaling_waste
                .record("spot-cross-model", self.params.local_redeploy_secs);
            // Remove from the old endpoint's roster if still listed.
            if let Some(ep) = self.endpoints.get_mut(&(old_model, region)) {
                ep.instances.retain(|&x| x != id);
            }
            self.reassign(id, model, region, pool, ready);
            return Some((id, ready));
        }
        // 3. fresh VM from the regional budget.
        if self.vm_budget[region.index()] > 0 {
            self.vm_budget[region.index()] -= 1;
            let local = self.local_weights[&region].contains(&model);
            let delay = if local {
                self.params.local_redeploy_secs
            } else {
                self.params.remote_redeploy_secs
            };
            metrics.scaling_waste.record(
                if local { "vm-local-deploy" } else { "vm-remote-deploy" },
                delay,
            );
            let id = self.spawn_instance(model, region, pool, InstState::Provisioning {
                until: now + delay,
            });
            return Some((id, now + delay));
        }
        None
    }

    fn reassign(&mut self, id: InstanceId, model: ModelKind, region: Region, pool: PoolTag, ready: Time) {
        let kv_cap = self.perf.profile(model).serving_kv_budget();
        let inst = &mut self.instances[id];
        debug_assert!(inst.batch.is_empty() && inst.waiting.is_empty());
        inst.model = model;
        inst.pool = pool;
        inst.kv_capacity = kv_cap;
        inst.kv_used = 0;
        inst.state = InstState::Provisioning { until: ready };
        let ep = self.endpoints.get_mut(&(model, region)).unwrap();
        if !ep.instances.contains(&id) {
            ep.instances.push(id);
        }
    }

    /// Scale in: drain the least-loaded active instance in a pool.  The
    /// instance converts to spot once its batch empties (engine calls
    /// [`Cluster::finish_drain`]).  Returns the drained instance id.
    pub fn scale_in(
        &mut self,
        model: ModelKind,
        region: Region,
        pool_filter: Option<PoolTag>,
    ) -> Option<InstanceId> {
        let ep = self.endpoints.get(&(model, region))?;
        let candidates: Vec<InstanceId> = ep
            .instances
            .iter()
            .copied()
            .filter(|&i| {
                let inst = &self.instances[i];
                inst.state == InstState::Active
                    && pool_filter.map_or(true, |p| inst.pool == p)
            })
            .collect();
        // Keep the robustness floor (min_instances) per endpoint, and at
        // least one active instance per pool (a siloed NIW pool must not
        // drain to zero and strand its tier).
        let active_total = self
            .endpoints[&(model, region)]
            .instances
            .iter()
            .filter(|&&i| self.instances[i].state == InstState::Active)
            .count();
        if active_total <= self.params.min_instances {
            return None;
        }
        if pool_filter.is_some() {
            // Pool-scoped scale-in (Siloed/Chiron): the robustness floor
            // applies per pool — §4's Fig 8 observation that Siloed holds
            // 2 IW + 2 NIW instances where Unified shares 2.
            if candidates.len() <= self.params.min_instances {
                return None;
            }
        }
        let id = candidates
            .into_iter()
            .min_by_key(|&i| self.instances[i].pending_tokens())?;
        self.instances[id].state = InstState::Draining;
        Some(id)
    }

    /// Move a fully drained instance to the spot pool.
    pub fn finish_drain(&mut self, id: InstanceId) {
        let inst = &mut self.instances[id];
        debug_assert!(inst.batch.is_empty());
        // Re-queue any stragglers left in its waiting queue (engine
        // re-routes them); state flip happens regardless.
        inst.state = InstState::Spot;
        inst.kv_used = 0;
        let (model, region) = (inst.model, inst.region);
        if let Some(ep) = self.endpoints.get_mut(&(model, region)) {
            ep.instances.retain(|&x| x != id);
        }
        self.spot_pool.get_mut(&region).unwrap().push(id);
    }

    /// Instances currently donated to spot, per region.
    pub fn spot_count(&self, region: Region) -> usize {
        self.spot_pool[&region].len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuKind;

    fn cluster() -> Cluster {
        Cluster::new(
            &ModelKind::EVAL4,
            PerfTable::new(GpuKind::A100x8, &ModelKind::EVAL4),
            ScalingParams::default(),
            &[(PoolTag::Unified, 3)],
            10,
        )
    }

    #[test]
    fn initial_layout() {
        let c = cluster();
        assert_eq!(c.instances.len(), 4 * 3 * 3);
        for &m in &ModelKind::EVAL4 {
            for r in Region::ALL {
                assert_eq!(c.active_instances(m, r).len(), 3);
            }
        }
    }

    #[test]
    fn scale_in_then_out_uses_spot_fast_path() {
        let mut c = cluster();
        let mut metrics = Metrics::default();
        let id = c.scale_in(ModelKind::Llama2_70B, Region::EastUs, None).unwrap();
        c.finish_drain(id);
        assert_eq!(c.spot_count(Region::EastUs), 1);
        let (id2, ready) = c
            .scale_out(ModelKind::Llama2_70B, Region::EastUs, PoolTag::Unified, 100.0, &mut metrics)
            .unwrap();
        assert_eq!(id, id2);
        assert!((ready - 160.0).abs() < 1e-9); // 1 min spot reclaim
        assert_eq!(c.spot_count(Region::EastUs), 0);
    }

    #[test]
    fn cross_model_spot_costs_redeploy() {
        let mut c = cluster();
        let mut metrics = Metrics::default();
        let id = c.scale_in(ModelKind::Bloom176B, Region::WestUs, None).unwrap();
        c.finish_drain(id);
        let (id2, ready) = c
            .scale_out(ModelKind::Llama2_70B, Region::WestUs, PoolTag::Unified, 0.0, &mut metrics)
            .unwrap();
        assert_eq!(id, id2);
        assert!((ready - 600.0).abs() < 1e-9); // 10 min redeploy
        assert_eq!(c.instances[id2].model, ModelKind::Llama2_70B);
        // KV capacity switched to the new model's profile.
        assert_eq!(
            c.instances[id2].kv_capacity,
            c.perf.profile(ModelKind::Llama2_70B).serving_kv_budget()
        );
    }

    #[test]
    fn fresh_vm_consumes_budget() {
        let mut c = cluster();
        let mut metrics = Metrics::default();
        let before = c.vm_budget[Region::EastUs.index()];
        let (_id, ready) = c
            .scale_out(ModelKind::Llama31_8B, Region::EastUs, PoolTag::Unified, 0.0, &mut metrics)
            .unwrap();
        assert_eq!(c.vm_budget[Region::EastUs.index()], before - 1);
        assert!((ready - 600.0).abs() < 1e-9);
    }

    #[test]
    fn remote_weights_cost_2h() {
        let mut c = cluster();
        c.local_weights.get_mut(&Region::WestUs).unwrap().retain(|&m| m != ModelKind::Bloom176B);
        let mut metrics = Metrics::default();
        let (_, ready) = c
            .scale_out(ModelKind::Bloom176B, Region::WestUs, PoolTag::Unified, 0.0, &mut metrics)
            .unwrap();
        assert!((ready - 7200.0).abs() < 1e-9);
    }

    #[test]
    fn min_instances_floor_respected() {
        let mut c = cluster();
        // 3 active; min is 2 ⇒ only one scale-in allowed.
        assert!(c.scale_in(ModelKind::Llama2_70B, Region::EastUs, None).is_some());
        assert!(c.scale_in(ModelKind::Llama2_70B, Region::EastUs, None).is_none());
    }

    #[test]
    fn max_instances_cap_respected() {
        let mut c = cluster();
        let mut metrics = Metrics::default();
        let mut added = 0;
        while c
            .scale_out(ModelKind::Llama32_3B, Region::CentralUs, PoolTag::Unified, 0.0, &mut metrics)
            .is_some()
        {
            added += 1;
            assert!(added < 100, "runaway scale-out");
        }
        // 3 initial + 10 regional VM budget = 13, still under the
        // max_instances cap of 20 — the budget binds first here.
        let got = c.allocated_count(ModelKind::Llama32_3B, Region::CentralUs);
        assert_eq!(got, 13);
        assert!(got <= c.params.max_instances);
    }

    #[test]
    fn no_capacity_reports_saturated_util() {
        let mut c = cluster();
        for &id in c.endpoints[&(ModelKind::Bloom176B, Region::WestUs)].instances.clone().iter() {
            c.instances[id].state = InstState::Draining;
        }
        assert_eq!(c.effective_util(ModelKind::Bloom176B, Region::WestUs), 1.0);
    }
}
