//! Discrete-event queue: a binary heap ordered by (time, sequence number).
//!
//! The sequence number makes ordering total and deterministic — two events
//! at the same timestamp pop in push order, which keeps simulations
//! reproducible run-to-run.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::config::Time;
use crate::sim::cluster::InstanceId;

/// Everything that can happen in the simulation besides request arrivals
/// (arrivals are merged in from the streaming trace iterator by the
/// engine, so a 10M-request trace never has to sit in the heap).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// An instance finished its current decode chunk.
    ChunkDone { instance: InstanceId },
    /// A provisioning instance becomes ready to serve.
    ProvisionDone { instance: InstanceId },
    /// Hourly forecast + ILP control epoch (§6.3).
    ControlEpoch,
    /// Fine-grained periodic tick: LT-U/LT-UA progression, utilization
    /// sampling, reactive re-checks.
    ScaleTick,
    /// Queue-manager aging scan (§6.2).
    QmTick,
    /// Fault plane: outage window `idx` of the
    /// [`FaultPlan`](crate::sim::faults::FaultPlan) opens — the region
    /// goes dark, its VMs are lost, in-flight work enters the retry path.
    FaultOutageStart {
        /// Index into `FaultPlan::outages`.
        idx: usize,
    },
    /// Fault plane: outage window `idx` closes — the availability mask
    /// lifts and replacement capacity is re-seeded.
    FaultOutageEnd {
        /// Index into `FaultPlan::outages`.
        idx: usize,
    },
    /// Fault plane: latency degradation window `idx` opens.
    FaultDegradeStart {
        /// Index into `FaultPlan::degradations`.
        idx: usize,
    },
    /// Fault plane: latency degradation window `idx` closes.
    FaultDegradeEnd {
        /// Index into `FaultPlan::degradations`.
        idx: usize,
    },
    /// Fault plane: spot-market preemption shock `idx` fires — the
    /// market reclaims part of every region's donated pool.
    FaultSpotShock {
        /// Index into `FaultPlan::spot_shocks`.
        idx: usize,
    },
    /// Fault plane: counter-seeded VM-crash hazard draw number `k`
    /// (the tick index seeds the RNG, so no generator state is carried
    /// across chunk handoffs).
    FaultCrashTick {
        /// 1-based tick index; tick `k` fires at `k × crash_check_secs`.
        k: u64,
    },
    /// A killed request's capped-exponential backoff expired: re-route
    /// it through failover routing.  Carries only the request id — the
    /// request itself (with its *original* arrival time, for SLA
    /// accounting) waits in the engine's pending-retry map, keeping
    /// this enum `Eq`-safe.
    RetryDue {
        /// Request id keying the engine's pending-retry map.
        id: u64,
    },
    /// Disaggregated serving: a prefilled request's KV-cache migration
    /// finished and the request is due for decode admission.  Carries
    /// only the request id — the request and its prefill-completion
    /// timestamp wait in the engine's pending-handoff map (same
    /// `Eq`-safe pattern as [`Event::RetryDue`]).
    HandoffDue {
        /// Request id keying the engine's pending-handoff map.
        id: u64,
    },
}

#[derive(Debug)]
struct Entry {
    time: Time,
    seq: u64,
    event: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for a min-heap on (time, seq).
        other
            .time
            .partial_cmp(&self.time)
            .expect("NaN event time")
            .then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    seq: u64,
}

impl EventQueue {
    /// An empty queue with the sequence counter at zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `event` at `time`.  The monotone sequence counter breaks
    /// same-time ties in push order; it is never reset, so moving the
    /// queue across a chunk handoff preserves pending tie-breaks.
    pub fn push(&mut self, time: Time, event: Event) {
        debug_assert!(time.is_finite(), "non-finite event time");
        self.heap.push(Entry { time, seq: self.seq, event });
        self.seq += 1;
    }

    /// Time of the earliest pending event.
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|e| e.time)
    }

    /// Remove and return the earliest pending event.
    pub fn pop(&mut self) -> Option<(Time, Event)> {
        self.heap.pop().map(|e| (e.time, e.event))
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, Event::ControlEpoch);
        q.push(1.0, Event::ScaleTick);
        q.push(2.0, Event::QmTick);
        assert_eq!(q.pop().unwrap().0, 1.0);
        assert_eq!(q.pop().unwrap().0, 2.0);
        assert_eq!(q.pop().unwrap().0, 3.0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_pop_in_push_order() {
        let mut q = EventQueue::new();
        q.push(1.0, Event::ChunkDone { instance: 7 });
        q.push(1.0, Event::ChunkDone { instance: 9 });
        assert_eq!(q.pop().unwrap().1, Event::ChunkDone { instance: 7 });
        assert_eq!(q.pop().unwrap().1, Event::ChunkDone { instance: 9 });
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = EventQueue::new();
        q.push(5.5, Event::ControlEpoch);
        assert_eq!(q.peek_time(), Some(5.5));
        assert_eq!(q.pop().unwrap().0, 5.5);
        assert_eq!(q.peek_time(), None);
    }
}
