//! One LLM model instance: chunked continuous batching over a KV-memory
//! budget — the simulator's unit of compute (one SplitWise instance).
//!
//! Execution model: the instance runs *decode chunks* of up to
//! `CHUNK_ITERS` iterations.  At each chunk boundary it (1) retires
//! sequences that finished during the chunk (their completion timestamps
//! were computed exactly when the chunk was scheduled — batches are
//! non-preemptible, §2.3), (2) admits new requests from its waiting queue
//! in scheduler-policy order while KV memory lasts, running their prefill
//! at the head of the next chunk, and (3) schedules the next chunk using
//! the perf model's prefill + per-iteration decode times.
//!
//! Memory accounting reserves input+output tokens at admission (vLLM-style
//! conservative reservation), which makes `kv_used / kv_capacity` — the
//! paper's *effective memory utilization* — a faithful load proxy.

use crate::config::{GpuKind, ModelKind, Region, Time};
use crate::perf::PerfProfile;
use crate::sim::cluster::{InstanceId, PoolTag};
use crate::trace::types::Request;

/// Decode iterations per scheduling chunk.  Smaller = finer-grained
/// admission (closer to true continuous batching — and a mid-chunk
/// arrival's extra TTFT wait is bounded by one chunk) but more events.
/// 8 iterations ≈ 0.2–0.4 s of decode for the 70B-class profiles, well
/// under the 1 s IW-F TTFT SLA.
pub const CHUNK_ITERS: u32 = 8;

/// Default max sequences decoding concurrently (vLLM-style running
/// cap).  The cap is per-SKU — [`crate::perf::PerfProfile::max_batch`]
/// is what [`crate::sim::cluster::Cluster::plan_next_chunk`] actually
/// passes to [`InstanceSim::admit`]; high-HBM SKUs (MI300-class) run
/// deeper.  This constant is the 640 GiB-SKU value, kept for tests and
/// as the documentation anchor.
pub const MAX_BATCH: usize = 64;

/// Which serving phase an instance executes (prefill/decode
/// disaggregation, SplitWise-style).  Assigned by the cluster roster
/// when disaggregation is enabled; every instance in a unified fleet
/// stays [`Phase::Unified`] and executes the exact pre-disaggregation
/// batch model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Classic colocated serving: prefill and decode on one instance.
    Unified,
    /// Prompt processing only — a sequence's instance-local work ends at
    /// prefill completion; its KV cache then migrates to a decode
    /// instance (the engine's handoff path).
    Prefill,
    /// Token generation only — admits handed-off prompts whose prefill
    /// already ran elsewhere, so admission carries no prompt-time cost.
    Decode,
}

/// Instance lifecycle (§2.3 provisioning, §6.4 scaling, spot donation).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InstState {
    /// VM allocated, model loading; unusable until `until`.
    Provisioning { until: Time },
    /// Serving traffic.
    Active,
    /// No new admissions; converts to spot when the batch drains.
    Draining,
    /// Donated to the spot pool (serving external traffic, reclaimable).
    Spot,
    /// VM lost to the fault plane (crash, region outage, or spot
    /// preemption).  Terminal: dead instances keep their arena slot so
    /// stale `ChunkDone`/`ProvisionDone` events resolve harmlessly, but
    /// they are out of every roster and never admit or schedule again.
    Dead,
}

/// A running sequence.
#[derive(Debug, Clone)]
pub struct ActiveSeq {
    /// The admitted request.
    pub req: Request,
    /// Output tokens still to generate at the *start* of the current chunk.
    pub remaining: u32,
    /// Reserved KV tokens (input + output).
    pub kv_reserved: u64,
    /// When this sequence's prefill completed (TTFT reference).
    pub prefill_done: Time,
    /// Region that actually served it (for metrics).
    pub served_region: Region,
    /// Set when the completion outcome was already recorded mid-chunk.
    pub completed_at: Option<Time>,
}

/// One simulated model instance.
#[derive(Debug)]
pub struct InstanceSim {
    /// Stable arena index in [`crate::sim::cluster::Cluster::instances`].
    pub id: InstanceId,
    /// Model whose weights are deployed here.
    pub model: ModelKind,
    /// Region the VM lives in.
    pub region: Region,
    /// Ownership pool (siloed IW/NIW or unified).
    pub pool: PoolTag,
    /// Hardware SKU of the underlying 8-GPU VM — fixed for the VM's
    /// life (weights redeploy across models, not across silicon).
    pub gpu: GpuKind,
    /// Serving phase (unified, or one side of a disaggregated pool).
    /// Owned by the cluster roster; [`Phase::Unified`] unless the run
    /// enables disaggregation.
    pub phase: Phase,
    /// Lifecycle state (provisioning / active / draining / spot).
    pub state: InstState,
    /// Sequences currently decoding.
    pub batch: Vec<ActiveSeq>,
    /// Requests routed here but not yet admitted to the batch.
    pub waiting: Vec<Request>,
    /// Cached Σ total_tokens over `waiting` (JSQ signal; O(1) reads).
    waiting_tokens: u64,
    /// Cached Σ remaining over `batch` (the running half of the JSQ
    /// signal; refreshed at chunk boundaries so reads stay O(1)).
    running_tokens: u64,
    /// Reserved KV tokens (running batch).
    pub kv_used: u64,
    /// KV-token capacity of this SKU (weights excluded).
    pub kv_capacity: u64,
    /// True when a ChunkDone event is in flight for this instance.
    pub chunk_scheduled: bool,
    /// End time of the chunk currently executing.
    pub busy_until: Time,
}

/// What [`InstanceSim::crash`] swept off a dying VM: sequences whose
/// completion already happened before the crash instant (their outcomes
/// are still recordable) and requests killed mid-flight (they re-enter
/// the coordinator through the retry path).
#[derive(Debug, Default)]
pub struct CrashedWork {
    /// Sequences that finished strictly before the crash (deferred
    /// outcome recording had not retired them yet).
    pub finished: Vec<ActiveSeq>,
    /// In-flight and queued requests killed by the VM loss.
    pub killed: Vec<Request>,
}

/// What a scheduled chunk will do — produced by [`InstanceSim::plan_chunk`]
/// so the engine can record completions/TTFTs with exact timestamps.
#[derive(Debug, Default)]
pub struct ChunkPlan {
    /// Chunk wall-clock duration.
    pub duration: Time,
    /// (batch index, completion time) for sequences finishing mid-chunk.
    pub completions: Vec<(usize, Time)>,
    /// (request id, prefill-done time) for sequences admitted this chunk.
    pub prefills: Vec<(u64, Time)>,
}

impl InstanceSim {
    /// A fresh instance with empty queues and zero KV reserved.
    pub fn new(
        id: InstanceId,
        model: ModelKind,
        region: Region,
        pool: PoolTag,
        gpu: GpuKind,
        state: InstState,
        kv_capacity: u64,
    ) -> Self {
        InstanceSim {
            id,
            model,
            region,
            pool,
            gpu,
            phase: Phase::Unified,
            state,
            batch: Vec::new(),
            waiting: Vec::new(),
            waiting_tokens: 0,
            running_tokens: 0,
            kv_used: 0,
            kv_capacity,
            chunk_scheduled: false,
            busy_until: 0.0,
        }
    }

    /// The paper's effective memory utilization: reserved KV over KV
    /// capacity (weights excluded from both sides).
    pub fn effective_util(&self) -> f64 {
        self.kv_used as f64 / self.kv_capacity.max(1) as f64
    }

    /// Tokens still queued + running (the JSQ routing signal, §6.1).
    /// O(1) — both halves are cached counters.
    pub fn pending_tokens(&self) -> u64 {
        self.waiting_tokens + self.running_tokens
    }

    /// Sum of queued (unadmitted) tokens — cached.
    pub fn waiting_tokens(&self) -> u64 {
        self.waiting_tokens
    }

    /// Recompute the cached token counters from the raw queues — the
    /// ground truth the incremental aggregates are checked against.
    /// Returns `(waiting_tokens, running_tokens)`.
    pub fn recount_tokens(&self) -> (u64, u64) {
        let waiting: u64 = self.waiting.iter().map(|r| r.total_tokens()).sum();
        let running: u64 = self.batch.iter().map(|s| s.remaining as u64).sum();
        (waiting, running)
    }

    /// Enqueue a request (keeps the token counter coherent).
    pub fn push_waiting(&mut self, req: Request) {
        self.waiting_tokens += req.total_tokens();
        self.waiting.push(req);
    }

    /// Drain the whole waiting queue (re-routing on drain/scale-in).
    pub fn take_waiting(&mut self) -> Vec<Request> {
        self.waiting_tokens = 0;
        std::mem::take(&mut self.waiting)
    }

    /// Can this instance take new work right now (active, not draining)?
    pub fn is_admitting(&self) -> bool {
        matches!(self.state, InstState::Active)
    }

    /// Retire sequences whose completion fell inside the finished chunk.
    /// Returns how many were retired (outcomes were already recorded, so
    /// the sequences themselves are dropped — no per-chunk allocation).
    /// `running_tokens` is untouched: a completed sequence's `remaining`
    /// was zeroed when its completion was planned.
    pub fn retire_completed(&mut self) -> usize {
        let mut done = 0;
        let mut i = 0;
        while i < self.batch.len() {
            if self.batch[i].completed_at.is_some() {
                let seq = self.batch.swap_remove(i);
                self.kv_used = self.kv_used.saturating_sub(seq.kv_reserved);
                done += 1;
            } else {
                i += 1;
            }
        }
        done
    }

    /// Fraction of the KV budget fresh (priority-1) NIW admissions may
    /// fill — spare-capacity serving that must not crowd out IW (§6.2).
    pub const NIW_ADMIT_CAP: f64 = 0.60;

    /// Admit from `waiting` (already in scheduler-policy order) while
    /// memory, batch slots and the per-chunk prefill budget last.
    ///
    /// * `prefill_budget_tokens` bounds the prompt tokens admitted into
    ///   one chunk, so a bulk admission cannot stall co-admitted IW TTFT
    ///   (the paper's NIW chunking — §6.2).
    /// * `max_batch` is the SKU's continuous-batching running cap
    ///   ([`crate::perf::PerfProfile::max_batch`]; high-HBM SKUs run
    ///   deeper).
    /// * Fresh NIW (still priority 1 at `now`) only fills up to
    ///   [`Self::NIW_ADMIT_CAP`] of the KV budget; IW and aged NIW use it
    ///   all.
    pub fn admit(
        &mut self,
        now: Time,
        prefill_budget_tokens: u64,
        max_batch: usize,
    ) -> Vec<Request> {
        // Scan the (policy-ordered) head for the admissible prefix, then
        // drain it in one pass — O(prefix) instead of O(Q) per admission.
        let mut take = 0usize;
        let mut prefill_tokens = 0u64;
        let mut kv_used = self.kv_used;
        while take < self.waiting.len() && self.batch.len() + take < max_batch {
            let head = &self.waiting[take];
            let need = head.total_tokens();
            // An oversized request on an empty batch is served anyway with
            // a truncated reservation (never wedge the queue).
            let oversized = self.batch.is_empty() && take == 0 && need > self.kv_capacity;
            if !oversized && kv_used + need > self.kv_capacity {
                break; // non-preemptible batch: wait for memory (§2.3)
            }
            let fresh_niw =
                !head.tier.is_interactive() && now - head.arrival <= 10.0 * 3600.0;
            if fresh_niw
                && (kv_used + need) as f64 > Self::NIW_ADMIT_CAP * self.kv_capacity as f64
            {
                break; // NIW only rides on spare capacity (queue is
                       // priority-partitioned, so nothing IW is behind it)
            }
            if take > 0 && prefill_tokens + head.input_tokens as u64 > prefill_budget_tokens {
                break; // prefill chunking: bound per-chunk prompt work
            }
            prefill_tokens += head.input_tokens as u64;
            kv_used += need.min(self.kv_capacity);
            take += 1;
        }
        self.kv_used = kv_used.min(self.kv_capacity.max(self.kv_used));
        let admitted: Vec<Request> = self.waiting.drain(..take).collect();
        let drained: u64 = admitted.iter().map(|r| r.total_tokens()).sum();
        self.waiting_tokens = self.waiting_tokens.saturating_sub(drained);
        admitted
    }

    /// Plan the next chunk at time `now`: prefill all `admitted`, then run
    /// up to [`CHUNK_ITERS`] decode iterations for the whole batch.
    ///
    /// Pushes the admitted requests into `batch` and returns the plan with
    /// exact completion/prefill timestamps.  Returns `None` if the batch
    /// is empty (instance goes idle).
    pub fn plan_chunk(
        &mut self,
        now: Time,
        admitted: Vec<Request>,
        perf: &PerfProfile,
    ) -> Option<ChunkPlan> {
        let prefill_tokens: u64 = admitted.iter().map(|r| r.input_tokens as u64).sum();
        // Decode-phase admissions carry no prompt cost: their prefill
        // already ran on a prefill instance and the KV arrived via the
        // handoff path.  The `_` arm is the exact pre-disaggregation
        // computation, so unified fleets stay bit-identical.
        let (prefill_time, prefill_done) = match self.phase {
            Phase::Decode => (0.0, now),
            _ => {
                let t = perf.prefill_time(prefill_tokens);
                (t, now + t)
            }
        };
        let mut plan = ChunkPlan::default();
        for req in admitted {
            plan.prefills.push((req.id, prefill_done));
            self.batch.push(ActiveSeq {
                kv_reserved: req.total_tokens(),
                remaining: req.output_tokens.max(1),
                prefill_done,
                served_region: self.region,
                completed_at: None,
                req,
            });
        }
        if self.batch.is_empty() {
            self.chunk_scheduled = false;
            self.running_tokens = 0;
            return None;
        }

        if self.phase == Phase::Prefill {
            // Prefill-only: every live sequence's instance-local work
            // ends at prefill completion — the decode half runs elsewhere
            // after the KV handoff, and the engine records these
            // completions as handoffs, not outcomes.
            for (i, seq) in self.batch.iter_mut().enumerate() {
                if seq.completed_at.is_none() {
                    seq.completed_at = Some(seq.prefill_done);
                    seq.remaining = 0;
                    plan.completions.push((i, seq.prefill_done));
                }
            }
            plan.duration = prefill_time;
            self.running_tokens = 0;
            self.busy_until = now + plan.duration;
            self.chunk_scheduled = true;
            return Some(plan);
        }

        let batch_n = self.batch.len();
        let tbt = perf.decode_iter_time(batch_n, self.kv_used);
        let max_remaining = self
            .batch
            .iter()
            .filter(|s| s.completed_at.is_none())
            .map(|s| s.remaining)
            .max()
            .unwrap_or(0);
        let iters = max_remaining.min(CHUNK_ITERS);
        for (i, seq) in self.batch.iter_mut().enumerate() {
            if seq.completed_at.is_some() {
                continue; // retired at the next chunk boundary
            }
            if seq.remaining <= iters {
                let t_done = prefill_done + seq.remaining as f64 * tbt;
                seq.completed_at = Some(t_done);
                seq.remaining = 0;
                plan.completions.push((i, t_done));
            } else {
                seq.remaining -= iters;
            }
        }
        plan.duration = prefill_time + iters as f64 * tbt;
        // Refresh the cached running-token counter once per chunk (the
        // admission pushes and per-sequence decrements above changed it).
        self.running_tokens = self.batch.iter().map(|s| s.remaining as u64).sum();
        self.busy_until = now + plan.duration;
        self.chunk_scheduled = true;
        Some(plan)
    }

    /// The fault plane kills this VM at `now`: the batch and waiting
    /// queue are swept into a [`CrashedWork`] report, every cached
    /// counter is zeroed (this runs inside
    /// [`Cluster::mutate`](crate::sim::cluster::Cluster::mutate), so the
    /// endpoint aggregates stay coherent), and the instance goes
    /// terminally [`InstState::Dead`].
    ///
    /// Sequences whose planned completion time is at or before `now`
    /// genuinely finished before the VM died — they are returned as
    /// `finished` so the engine can still record their outcomes;
    /// everything else is `killed` and re-enters via the retry path.
    pub fn crash(&mut self, now: Time) -> CrashedWork {
        let mut work = CrashedWork { killed: self.take_waiting(), ..CrashedWork::default() };
        for seq in self.batch.drain(..) {
            match seq.completed_at {
                Some(t) if t <= now => work.finished.push(seq),
                _ => work.killed.push(seq.req),
            }
        }
        self.kv_used = 0;
        self.running_tokens = 0;
        self.chunk_scheduled = false;
        self.busy_until = now;
        self.state = InstState::Dead;
        work
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuKind, Region, Tier};
    use crate::trace::types::AppKind;

    fn perf() -> PerfProfile {
        PerfProfile::get(ModelKind::Llama2_70B, GpuKind::H100x8)
    }

    fn req(id: u64, input: u32, output: u32) -> Request {
        Request {
            id,
            arrival: 0.0,
            model: ModelKind::Llama2_70B,
            origin: Region::EastUs,
            tier: Tier::IwF,
            app: AppKind::Chat,
            input_tokens: input,
            output_tokens: output,
        }
    }

    fn inst() -> InstanceSim {
        InstanceSim::new(0, ModelKind::Llama2_70B, Region::EastUs, PoolTag::Unified,
                         GpuKind::H100x8, InstState::Active, 100_000)
    }

    #[test]
    fn admit_respects_memory() {
        let mut i = inst();
        i.push_waiting(req(1, 60_000, 10_000));
        i.push_waiting(req(2, 40_000, 10_000)); // would exceed 100k
        let admitted = i.admit(0.0, u64::MAX, MAX_BATCH);
        assert_eq!(admitted.len(), 1);
        assert_eq!(i.kv_used, 70_000);
        assert_eq!(i.waiting.len(), 1);
    }

    #[test]
    fn admit_respects_batch_cap() {
        let mut i = inst();
        for n in 0..(MAX_BATCH + 10) {
            i.push_waiting(req(n as u64, 10, 10));
        }
        let admitted = i.admit(0.0, u64::MAX, MAX_BATCH);
        assert_eq!(admitted.len(), MAX_BATCH);
    }

    #[test]
    fn short_request_completes_within_first_chunk() {
        let mut i = inst();
        i.push_waiting(req(1, 1000, 6)); // 6 < CHUNK_ITERS
        let adm = i.admit(0.0, u64::MAX, MAX_BATCH);
        let plan = i.plan_chunk(0.0, adm, &perf()).unwrap();
        assert_eq!(plan.completions.len(), 1);
        let p = perf();
        let expect_prefill = p.prefill_time(1000);
        let tbt = p.decode_iter_time(1, 1006);
        let expect_done = expect_prefill + 6.0 * tbt;
        assert!((plan.completions[0].1 - expect_done).abs() < 1e-9);
        // Chunk runs only as long as the longest remaining need.
        assert!((plan.duration - expect_done).abs() < 1e-9);
    }

    #[test]
    fn long_request_spans_chunks() {
        let mut i = inst();
        i.push_waiting(req(1, 1000, 200));
        let adm = i.admit(0.0, u64::MAX, MAX_BATCH);
        let plan = i.plan_chunk(0.0, adm, &perf()).unwrap();
        assert!(plan.completions.is_empty());
        assert_eq!(i.batch[0].remaining, 200 - CHUNK_ITERS);
        // Next chunks (no admissions) keep decoding.
        let plan2 = i.plan_chunk(plan.duration, vec![], &perf()).unwrap();
        assert!(plan2.prefills.is_empty());
        assert_eq!(i.batch[0].remaining, 200 - 2 * CHUNK_ITERS);
        // Drive to completion exactly like the engine: retire then plan.
        // ceil(200 / CHUNK_ITERS) chunks to finish.
        let mut chunks = 2;
        loop {
            i.retire_completed();
            match i.plan_chunk(10.0, vec![], &perf()) {
                Some(p) => {
                    chunks += 1;
                    if !p.completions.is_empty() {
                        break;
                    }
                }
                None => panic!("batch drained without completing"),
            }
            assert!(chunks < 40, "did not converge");
        }
        assert_eq!(chunks as u32, (200 + CHUNK_ITERS - 1) / CHUNK_ITERS);
    }

    #[test]
    fn retire_frees_memory() {
        let mut i = inst();
        i.push_waiting(req(1, 100, 8)); // completes within one chunk
        let adm = i.admit(0.0, u64::MAX, MAX_BATCH);
        assert_eq!(i.kv_used, 108);
        i.plan_chunk(0.0, adm, &perf()).unwrap();
        let done = i.retire_completed();
        assert_eq!(done, 1);
        assert_eq!(i.kv_used, 0);
        assert!(i.batch.is_empty());
        assert_eq!(i.recount_tokens(), (0, 0));
        assert_eq!(i.pending_tokens(), 0);
    }

    #[test]
    fn empty_batch_goes_idle() {
        let mut i = inst();
        assert!(i.plan_chunk(0.0, vec![], &perf()).is_none());
        assert!(!i.chunk_scheduled);
    }

    fn niw_req(id: u64, arrival: f64, input: u32, output: u32) -> Request {
        Request {
            id,
            arrival,
            model: ModelKind::Llama2_70B,
            origin: Region::EastUs,
            tier: Tier::Niw,
            app: AppKind::DocSummary,
            input_tokens: input,
            output_tokens: output,
        }
    }

    #[test]
    fn fresh_niw_capped_at_spare_capacity() {
        let mut i = inst(); // capacity 100k
        // Three fresh NIW requests of 25k each: the third would push past
        // the 60% cap and must stay queued.
        for n in 0..3 {
            i.push_waiting(niw_req(n, 0.0, 20_000, 5_000));
        }
        let admitted = i.admit(100.0, u64::MAX, MAX_BATCH);
        assert_eq!(admitted.len(), 2);
        assert_eq!(i.kv_used, 50_000);
        assert_eq!(i.waiting.len(), 1);
    }

    #[test]
    fn aged_niw_uses_full_capacity() {
        let mut i = inst();
        for n in 0..3 {
            i.push_waiting(niw_req(n, 0.0, 20_000, 5_000));
        }
        // 11 hours later the requests are priority 0 (aged past 10 h).
        let admitted = i.admit(11.0 * 3600.0, u64::MAX, MAX_BATCH);
        assert_eq!(admitted.len(), 3);
    }

    #[test]
    fn iw_ignores_niw_cap() {
        let mut i = inst();
        for n in 0..3 {
            i.push_waiting(req(n, 20_000, 5_000)); // IW-F
        }
        let admitted = i.admit(0.0, u64::MAX, MAX_BATCH);
        assert_eq!(admitted.len(), 3);
    }

    #[test]
    fn prefill_budget_chunks_admissions() {
        let mut i = inst();
        for n in 0..4 {
            i.push_waiting(req(n, 10_000, 100));
        }
        // Budget of 15k prompt tokens: first request always admitted,
        // second would exceed ⇒ chunked to one per call.
        let admitted = i.admit(0.0, 15_000, MAX_BATCH);
        assert_eq!(admitted.len(), 1);
        let admitted = i.admit(0.0, 15_000, MAX_BATCH);
        assert_eq!(admitted.len(), 1);
    }

    #[test]
    fn oversized_request_served_with_truncated_reservation() {
        let mut i = inst();
        i.push_waiting(req(1, 90_000, 20_000)); // 110k > 100k capacity
        let admitted = i.admit(0.0, u64::MAX, MAX_BATCH);
        assert_eq!(admitted.len(), 1);
        assert!(i.kv_used <= i.kv_capacity);
    }

    #[test]
    fn prefill_phase_completes_at_prefill_done() {
        let mut i = inst();
        i.phase = Phase::Prefill;
        i.push_waiting(req(1, 1000, 200)); // long decode — irrelevant here
        let adm = i.admit(0.0, u64::MAX, MAX_BATCH);
        let plan = i.plan_chunk(0.0, adm, &perf()).unwrap();
        assert_eq!(plan.completions.len(), 1);
        let expect = perf().prefill_time(1000);
        assert!((plan.completions[0].1 - expect).abs() < 1e-9);
        assert!((plan.duration - expect).abs() < 1e-9);
        assert_eq!(i.pending_tokens(), 0, "no decode work is retained");
        i.retire_completed();
        assert!(i.batch.is_empty());
        assert_eq!(i.kv_used, 0);
        // Idle afterwards: nothing left to schedule.
        assert!(i.plan_chunk(plan.duration, vec![], &perf()).is_none());
    }

    #[test]
    fn decode_phase_skips_prefill_cost() {
        let mut i = inst();
        i.phase = Phase::Decode;
        i.push_waiting(req(1, 1000, 6));
        let adm = i.admit(0.0, u64::MAX, MAX_BATCH);
        let plan = i.plan_chunk(0.0, adm, &perf()).unwrap();
        assert_eq!(plan.completions.len(), 1);
        let tbt = perf().decode_iter_time(1, 1006);
        assert!((plan.completions[0].1 - 6.0 * tbt).abs() < 1e-9);
        // Prefill timestamps degenerate to "now": TTFT for handed-off
        // sequences comes from the engine's handoff bookkeeping.
        assert!((plan.prefills[0].1 - 0.0).abs() < 1e-12);
    }

    #[test]
    fn crash_splits_finished_from_killed_and_zeroes_state() {
        let mut i = inst();
        i.push_waiting(req(1, 100, 6)); // finishes inside the first chunk
        i.push_waiting(req(2, 1000, 200)); // spans many chunks
        let adm = i.admit(0.0, u64::MAX, MAX_BATCH);
        let plan = i.plan_chunk(0.0, adm, &perf()).unwrap();
        assert_eq!(plan.completions.len(), 1);
        i.push_waiting(req(3, 50, 50)); // arrives mid-chunk, still queued
        // Crash after the short request finished but before the chunk ends.
        let work = i.crash(plan.completions[0].1 + 1e-6);
        assert_eq!(work.finished.len(), 1);
        assert_eq!(work.finished[0].req.id, 1);
        let mut killed: Vec<u64> = work.killed.iter().map(|r| r.id).collect();
        killed.sort_unstable();
        assert_eq!(killed, vec![2, 3]);
        assert_eq!(i.state, InstState::Dead);
        assert!(i.batch.is_empty() && i.waiting.is_empty());
        assert_eq!(i.kv_used, 0);
        assert_eq!(i.pending_tokens(), 0);
        assert!(!i.chunk_scheduled);
        assert_eq!(i.recount_tokens(), (0, 0));
    }

    #[test]
    fn util_is_kv_fraction() {
        let mut i = inst();
        i.push_waiting(req(1, 30_000, 20_000));
        let adm = i.admit(0.0, u64::MAX, MAX_BATCH);
        i.plan_chunk(0.0, adm, &perf()).unwrap();
        assert!((i.effective_util() - 0.5).abs() < 1e-9);
    }
}
