//! Epoch-sliced chunked execution for a single simulation run
//! (ROADMAP item 1).
//!
//! The sequential engine streams arrivals lazily but generates them
//! *inline* on the simulation thread, and the sweep path materializes
//! the whole `Arc<[Request]>` buffer up front — O(trace) memory that a
//! 30-day, 10M-request/day run cannot afford.  This module partitions
//! the trace into control-epoch-aligned chunks and pipelines them:
//! generator workers (the `experiments::sweep` scoped-pool pattern)
//! produce chunk k+1..k+W through a bounded reorder window while the
//! simulation thread consumes chunk k, so peak memory is O(chunk) and
//! generation cost overlaps simulation instead of serializing with it.
//!
//! Between chunks the simulator state is detached and re-attached as an
//! explicit [`SimHandoff`](crate::sim::engine::SimHandoff) — every
//! boundary exercises the full suspend/resume path, which is how the
//! headline invariant is kept honest: chunked execution is
//! **bit-identical** to the sequential engine for every strategy, fleet,
//! chunk size and worker count (`tests/chunked_equivalence.rs`).
//!
//! Chunk boundaries land on multiples of the chunk length, which is a
//! whole number of control intervals — so the hourly
//! `Event::ControlEpoch` barrier always falls on a boundary, never
//! inside a straddling arrival slice.  (Bit-identity holds for *any*
//! cut points by construction; epoch alignment keeps the forecast/ILP
//! cadence and the chunk cadence in phase, which is what makes the
//! per-boundary handoff a natural checkpoint.)

use std::collections::BTreeMap;
use std::sync::{Condvar, Mutex};

use crate::config::MINUTE;
use crate::sim::engine::{SimConfig, Simulation};
use crate::trace::generator::TraceGenerator;
use crate::trace::types::Request;

/// Knobs for [`run_chunked`].
#[derive(Debug, Clone)]
pub struct ChunkedOptions {
    /// Chunk length in control epochs (chunk seconds =
    /// `chunk_epochs × ScalingParams::control_interval`, rounded to
    /// whole generator minutes).  1 = handoff every epoch; 24 = daily
    /// chunks on the default hourly interval.
    pub chunk_epochs: usize,
    /// Generator worker threads; 0 = auto (`available_parallelism - 1`,
    /// at least 1).  The reorder window admits `workers + 1` chunks, so
    /// peak buffered memory is O(workers × chunk) regardless of trace
    /// length.
    pub workers: usize,
}

impl Default for ChunkedOptions {
    fn default() -> Self {
        ChunkedOptions { chunk_epochs: 3, workers: 0 }
    }
}

/// Run an already-built simulation chunk-by-chunk to completion.
///
/// Source selection mirrors [`Simulation::run`]: a replay CSV or shared
/// buffer is sliced in place by arrival time (already materialized, so
/// the pipeline would only add copies); otherwise the generator is
/// pipelined on worker threads.  Every chunk boundary performs a full
/// [`suspend`](Simulation::suspend)/[`resume`](Simulation::resume)
/// handoff, and the drain phase ([`Simulation::finish`]) runs once after
/// the final chunk.
pub fn run_chunked(sim: Simulation, opts: &ChunkedOptions) -> Simulation {
    let chunk_secs =
        (sim.cfg.scaling.control_interval * opts.chunk_epochs.max(1) as f64).max(MINUTE);
    let mut sim = if let Some(path) = sim.cfg.replay_trace.clone() {
        let reqs = crate::trace::io::read_csv(&path)
            .expect("read replay trace (CSV with header)");
        run_buffer_chunks(sim, &reqs, chunk_secs)
    } else if let Some(buf) = sim.cfg.shared_trace.clone() {
        run_buffer_chunks(sim, &buf, chunk_secs)
    } else {
        run_pipelined(sim, chunk_secs, opts.workers)
    };
    sim.finish();
    sim
}

/// Convenience: build and run a simulation through the chunked executor.
pub fn run_simulation_chunked(cfg: SimConfig, opts: &ChunkedOptions) -> Simulation {
    run_chunked(Simulation::new(cfg), opts)
}

/// One explicit state handoff: detach everything mutable, re-attach,
/// continue.  Done at every chunk boundary so the roundtrip can never
/// silently rot.
fn handoff_roundtrip(sim: Simulation) -> Simulation {
    let (cfg, handoff) = sim.suspend();
    Simulation::resume(cfg, handoff)
}

/// Chunked execution over a pre-materialized, time-ordered buffer
/// (replay CSV or `shared_trace`): slice by arrival time at multiples of
/// `chunk_secs`.  Ids come with the buffer.
fn run_buffer_chunks(mut sim: Simulation, buf: &[Request], chunk_secs: f64) -> Simulation {
    let mut start = 0usize;
    let mut boundary_idx = 1u64;
    while start < buf.len() {
        let boundary = boundary_idx as f64 * chunk_secs;
        let end = start + buf[start..].partition_point(|r| r.arrival < boundary);
        if end > start {
            let next_after = buf.get(end).map(|r| r.arrival);
            sim.run_chunk(buf[start..end].iter().copied(), next_after);
            sim = handoff_roundtrip(sim);
            start = end;
        }
        boundary_idx += 1;
    }
    sim
}

/// Generation→simulation pipeline: workers claim chunk indices through a
/// bounded reorder window and publish generated buffers; the simulation
/// thread consumes them in order, assigns ids sequentially (identical to
/// the streaming path), and keeps one non-empty chunk of lookahead to
/// know the successor's first arrival time.
fn run_pipelined(sim: Simulation, chunk_secs: f64, workers: usize) -> Simulation {
    let gen = TraceGenerator::new(sim.cfg.trace.clone());
    let total_minutes = gen.total_minutes();
    let chunk_minutes = ((chunk_secs / MINUTE).round() as u64).max(1);
    let n_chunks = ((total_minutes + chunk_minutes - 1) / chunk_minutes) as usize;
    if n_chunks == 0 {
        return sim;
    }
    let workers = if workers == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .saturating_sub(1)
            .max(1)
    } else {
        workers
    }
    .min(n_chunks);

    let exchange = ChunkExchange::new(n_chunks, workers + 1);
    let (gen_ref, ex_ref) = (&gen, &exchange);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(move || loop {
                let c = match ex_ref.claim() {
                    Some(c) => c,
                    None => break,
                };
                let lo = c as u64 * chunk_minutes;
                let hi = (lo + chunk_minutes).min(total_minutes);
                ex_ref.publish(c, gen_ref.generate_window(lo, hi));
            });
        }

        // Consumer (this thread).  Ids are assigned in receive order,
        // which is chunk order, which is global arrival order — the
        // same numbering `TraceGenerator::stream` produces.
        let mut sim = sim;
        let mut cursor = 0usize;
        let mut next_id = 0u64;
        let fetch_nonempty = |cursor: &mut usize, next_id: &mut u64| -> Option<Vec<Request>> {
            while *cursor < n_chunks {
                let mut buf = ex_ref.recv(*cursor);
                *cursor += 1;
                if !buf.is_empty() {
                    for r in &mut buf {
                        r.id = *next_id;
                        *next_id += 1;
                    }
                    return Some(buf);
                }
            }
            None
        };
        let mut cur = fetch_nonempty(&mut cursor, &mut next_id);
        while let Some(buf) = cur {
            // One chunk of lookahead: the successor's first arrival is
            // this chunk's event-processing horizon.  Empty chunks are
            // skipped — their events simply run at the head of the next
            // non-empty chunk, in the identical pop order.
            let nxt = fetch_nonempty(&mut cursor, &mut next_id);
            let next_after = nxt.as_ref().map(|b| b[0].arrival);
            sim.run_chunk(buf.iter().copied(), next_after);
            sim = handoff_roundtrip(sim);
            cur = nxt;
        }
        sim
    })
}

/// Bounded reorder window between generator workers and the simulation
/// thread.  Workers `claim` the next unclaimed chunk index — blocking
/// while the window is full — generate it, and `publish` the buffer; the
/// consumer `recv`s strictly in index order, which opens window space.
/// At most `window` published-but-unconsumed chunks exist at any time,
/// so buffered memory is bounded by O(window × chunk) for any trace
/// length.
struct ChunkExchange {
    state: Mutex<ExchangeState>,
    /// Signalled on `publish`; the consumer waits here for its index.
    ready_cv: Condvar,
    /// Signalled on `recv`; claiming workers wait here for window space.
    space_cv: Condvar,
    n_chunks: usize,
    window: usize,
}

struct ExchangeState {
    /// Next chunk index no worker has claimed yet.
    next_claim: usize,
    /// Number of chunks the consumer has received (= next index it needs).
    consumed: usize,
    /// Published chunks awaiting consumption, keyed by index.
    ready: BTreeMap<usize, Vec<Request>>,
}

impl ChunkExchange {
    fn new(n_chunks: usize, window: usize) -> Self {
        ChunkExchange {
            state: Mutex::new(ExchangeState {
                next_claim: 0,
                consumed: 0,
                ready: BTreeMap::new(),
            }),
            ready_cv: Condvar::new(),
            space_cv: Condvar::new(),
            n_chunks,
            // ≥ 2 so the consumer's one-chunk lookahead can never
            // deadlock against a full window.
            window: window.max(2),
        }
    }

    /// Claim the next chunk index to generate, or `None` when the whole
    /// trace has been claimed.  Blocks while the reorder window is full.
    fn claim(&self) -> Option<usize> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.next_claim >= self.n_chunks {
                return None;
            }
            if st.next_claim < st.consumed + self.window {
                let c = st.next_claim;
                st.next_claim += 1;
                return Some(c);
            }
            st = self.space_cv.wait(st).unwrap();
        }
    }

    /// Publish a generated chunk under its index.
    fn publish(&self, c: usize, buf: Vec<Request>) {
        let mut st = self.state.lock().unwrap();
        st.ready.insert(c, buf);
        self.ready_cv.notify_all();
    }

    /// Receive chunk `c` (the consumer calls with strictly increasing
    /// `c`), blocking until its worker publishes it.
    fn recv(&self, c: usize) -> Vec<Request> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(buf) = st.ready.remove(&c) {
                st.consumed = c + 1;
                self.space_cv.notify_all();
                return buf;
            }
            st = self.ready_cv.wait(st).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::{quick_config, run_simulation, Strategy};

    #[test]
    fn chunked_generator_path_matches_sequential() {
        let mk = || {
            let mut cfg = quick_config(Strategy::LtUa, 0.1, 0.005);
            cfg.scaling.max_instances = 10;
            cfg
        };
        let seq = run_simulation(mk());
        assert!(seq.metrics.completed > 0);
        let ch = run_simulation_chunked(mk(), &ChunkedOptions { chunk_epochs: 1, workers: 2 });
        assert!(seq.metrics == ch.metrics);
    }

    #[test]
    fn chunked_shared_buffer_path_matches_sequential() {
        let mk = || {
            let mut cfg = quick_config(Strategy::Reactive, 0.1, 0.005);
            cfg.scaling.max_instances = 10;
            cfg
        };
        let seq = run_simulation(mk());
        let mut cfg = mk();
        cfg.shared_trace = Some(TraceGenerator::new(cfg.trace.clone()).materialize_shared());
        let ch = run_simulation_chunked(cfg, &ChunkedOptions { chunk_epochs: 1, workers: 2 });
        assert!(seq.metrics == ch.metrics);
    }
}
