//! Load forecasting (§6.3): the `Forecaster` trait plus three
//! implementations —
//!
//! * [`PjrtForecaster`] — the production path: the AOT-compiled Layer-2
//!   seasonal-AR graph (with its Layer-1 Pallas recursion kernel) executed
//!   via PJRT once per control epoch.
//! * [`NativeArForecaster`] — a pure-Rust replica of the same pipeline
//!   (seasonal differencing → CSS AR(p) fit → iterated forecast).  Used by
//!   tests, by artifact-less environments, and to cross-validate the PJRT
//!   path bit-for-bit at f32 tolerance.
//! * [`SeasonalNaive`] — ŷ[t+h] = mean of y at the same phase on previous
//!   days; the forecasting baseline.

use crate::runtime::ForecastExecutable;

/// Multi-series TPS forecaster.  `history` is `[series][t]` (time
/// ascending, 15-minute buckets); returns `[series][h]`.
pub trait Forecaster {
    /// Number of future buckets one [`Forecaster::forecast`] call emits.
    fn horizon(&self) -> usize;
    /// Forecast every series `horizon` buckets ahead: `history` is
    /// `[series][t]` (time ascending), the result is `[series][h]`.
    fn forecast(&mut self, history: &[Vec<f64>]) -> Vec<Vec<f64>>;
    /// Stable identifier for reports and CSV labels.
    fn name(&self) -> &'static str;
}

/// Seasonal-naive baseline: average the same phase over the last `k` days.
pub struct SeasonalNaive {
    /// Buckets per season (96 = one day of 15-minute buckets).
    pub season: usize,
    /// Buckets forecast per call.
    pub horizon: usize,
    /// How many previous same-phase days are averaged (`k`).
    pub days_averaged: usize,
}

impl SeasonalNaive {
    /// Baseline with the default 3-day same-phase average.
    pub fn new(season: usize, horizon: usize) -> Self {
        SeasonalNaive { season, horizon, days_averaged: 3 }
    }
}

impl Forecaster for SeasonalNaive {
    fn horizon(&self) -> usize {
        self.horizon
    }

    fn name(&self) -> &'static str {
        "seasonal-naive"
    }

    fn forecast(&mut self, history: &[Vec<f64>]) -> Vec<Vec<f64>> {
        history
            .iter()
            .map(|series| {
                let t = series.len();
                (0..self.horizon)
                    .map(|h| {
                        let mut acc = 0.0;
                        let mut n = 0usize;
                        for d in 1..=self.days_averaged {
                            let idx = t as i64 + h as i64 - (d * self.season) as i64;
                            if idx >= 0 && (idx as usize) < t {
                                acc += series[idx as usize];
                                n += 1;
                            }
                        }
                        if n == 0 {
                            *series.last().unwrap_or(&0.0)
                        } else {
                            (acc / n as f64).max(0.0)
                        }
                    })
                    .collect()
            })
            .collect()
    }
}

/// Pure-Rust seasonal-AR pipeline — the same math as
/// `python/compile/forecast_graph.py` (seasonal difference, ridge CSS fit,
/// iterated forecast, seasonal re-integration).
pub struct NativeArForecaster {
    /// Buckets per season (the differencing lag `m`).
    pub season: usize,
    /// AR order `p` (lags in the CSS fit).
    pub order: usize,
    /// Buckets forecast per call.
    pub horizon: usize,
    /// Ridge regularizer added to the normal-equation diagonal.
    pub ridge: f64,
}

impl NativeArForecaster {
    /// Forecaster with the pipeline's default ridge (1e-3).
    pub fn new(season: usize, order: usize, horizon: usize) -> Self {
        NativeArForecaster { season, order, horizon, ridge: 1e-3 }
    }

    /// CSS AR(p) fit on one differenced series.  Returns (coefs newest-lag
    /// -first, intercept).
    fn fit(&self, diff: &[f64]) -> (Vec<f64>, f64) {
        let p = self.order;
        let rows = diff.len().saturating_sub(p);
        let n = p + 1;
        // Normal equations: gram = X'X + ridge·I, rhs = X'y with
        // X[t, i] = diff[t + p - 1 - i], y[t] = diff[t + p].
        let mut gram = vec![0.0f64; n * n];
        let mut rhs = vec![0.0f64; n];
        for t in 0..rows {
            let y = diff[t + p];
            for i in 0..p {
                let xi = diff[t + p - 1 - i];
                rhs[i] += xi * y;
                for j in i..p {
                    gram[i * n + j] += xi * diff[t + p - 1 - j];
                }
                gram[i * n + p] += xi; // intercept column
            }
            rhs[p] += y;
            gram[p * n + p] += 1.0;
        }
        // Mirror the upper triangle and add ridge.
        for i in 0..n {
            for j in 0..i {
                gram[i * n + j] = gram[j * n + i];
            }
            gram[i * n + i] += self.ridge;
        }
        let beta = solve_dense(&mut gram, &mut rhs, n);
        (beta[..p].to_vec(), beta[p])
    }
}

/// Gauss-Jordan with partial pivoting on a dense n×n system (in place).
fn solve_dense(a: &mut [f64], b: &mut [f64], n: usize) -> Vec<f64> {
    for col in 0..n {
        // Pivot.
        let mut piv = col;
        for r in col + 1..n {
            if a[r * n + col].abs() > a[piv * n + col].abs() {
                piv = r;
            }
        }
        if piv != col {
            for c in 0..n {
                a.swap(col * n + c, piv * n + c);
            }
            b.swap(col, piv);
        }
        let d = a[col * n + col];
        if d.abs() < 1e-12 {
            continue; // singular direction; ridge normally prevents this
        }
        for c in 0..n {
            a[col * n + c] /= d;
        }
        b[col] /= d;
        for r in 0..n {
            if r != col {
                let f = a[r * n + col];
                if f != 0.0 {
                    for c in 0..n {
                        a[r * n + c] -= f * a[col * n + c];
                    }
                    b[r] -= f * b[col];
                }
            }
        }
    }
    b.to_vec()
}

impl Forecaster for NativeArForecaster {
    fn horizon(&self) -> usize {
        self.horizon
    }

    fn name(&self) -> &'static str {
        "native-seasonal-ar"
    }

    fn forecast(&mut self, history: &[Vec<f64>]) -> Vec<Vec<f64>> {
        let m = self.season;
        let p = self.order;
        history
            .iter()
            .map(|series| {
                let t = series.len();
                if t < m + p + 8 {
                    // Not enough history: fall back to persistence.
                    let last = *series.last().unwrap_or(&0.0);
                    return vec![last.max(0.0); self.horizon];
                }
                let diff: Vec<f64> = (m..t).map(|i| series[i] - series[i - m]).collect();
                let (coefs, icept) = self.fit(&diff);
                // Iterated forecast on the differenced series.
                let mut lags: Vec<f64> = diff[diff.len() - p..].iter().rev().copied().collect();
                let mut out = Vec::with_capacity(self.horizon);
                for h in 0..self.horizon {
                    let mut nxt = icept;
                    for i in 0..p {
                        nxt += coefs[i] * lags[i];
                    }
                    // Seasonal re-integration: ŷ[T+h] = d̂ + y[T+h-m].
                    let base = series[t + h - m];
                    out.push((nxt + base).max(0.0));
                    lags.rotate_right(1);
                    lags[0] = nxt;
                }
                out
            })
            .collect()
    }
}

/// PJRT-backed forecaster: pads/truncates the series set to the
/// artifact's fixed `[S, T]` shape and executes the compiled graph.
pub struct PjrtForecaster {
    exe: ForecastExecutable,
}

impl PjrtForecaster {
    /// Load the compiled forecast executable from the artifacts
    /// directory (produced by `make artifacts`).
    pub fn load(artifacts_dir: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        Ok(PjrtForecaster { exe: ForecastExecutable::load(artifacts_dir)? })
    }

    /// The artifact's fixed `(n_series, history, horizon)` shape.
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.exe.shape.n_series, self.exe.shape.history, self.exe.shape.horizon)
    }
}

impl Forecaster for PjrtForecaster {
    fn horizon(&self) -> usize {
        self.exe.shape.horizon
    }

    fn name(&self) -> &'static str {
        "pjrt-seasonal-ar"
    }

    fn forecast(&mut self, history: &[Vec<f64>]) -> Vec<Vec<f64>> {
        let (s_max, t_fix, h) = (self.exe.shape.n_series, self.exe.shape.history, self.horizon());
        assert!(
            history.len() <= s_max,
            "artifact supports {s_max} series, got {}",
            history.len()
        );
        let mut flat = vec![0f32; s_max * t_fix];
        for (s, series) in history.iter().enumerate() {
            assert!(series.len() >= t_fix, "need {t_fix} history points, got {}", series.len());
            let tail = &series[series.len() - t_fix..];
            for (i, &v) in tail.iter().enumerate() {
                flat[s * t_fix + i] = v as f32;
            }
        }
        let out = self.exe.forecast(&flat).expect("pjrt forecast");
        history
            .iter()
            .enumerate()
            .map(|(s, _)| (0..h).map(|i| out[s * h + i] as f64).collect())
            .collect()
    }
}

/// Mean absolute percentage error of a forecast against actuals.
pub fn mape(forecast: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(forecast.len(), actual.len());
    let mut acc = 0.0;
    for (f, a) in forecast.iter().zip(actual) {
        acc += (f - a).abs() / a.abs().max(1.0);
    }
    acc / forecast.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diurnal(series: usize, len: usize, season: usize) -> Vec<Vec<f64>> {
        (0..series)
            .map(|s| {
                (0..len)
                    .map(|t| {
                        let phase = 2.0 * std::f64::consts::PI * (t % season) as f64 / season as f64;
                        100.0 * (s + 1) as f64 * (1.0 + 0.5 * phase.sin())
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn seasonal_naive_repeats_phase() {
        let hist = diurnal(2, 96 * 4, 96);
        let mut f = SeasonalNaive::new(96, 4);
        let out = f.forecast(&hist);
        // Clean periodic signal: prediction equals the same phase yesterday.
        for h in 0..4 {
            let expect = hist[0][96 * 3 + h];
            assert!((out[0][h] - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn native_ar_accurate_on_diurnal() {
        let season = 96;
        let full = diurnal(3, season * 7 + 4, season);
        let hist: Vec<Vec<f64>> = full.iter().map(|s| s[..season * 7].to_vec()).collect();
        let mut f = NativeArForecaster::new(season, 8, 4);
        let out = f.forecast(&hist);
        for s in 0..3 {
            let actual = &full[s][season * 7..];
            let err = mape(&out[s], actual);
            assert!(err < 0.05, "series {s} mape {err}");
        }
    }

    #[test]
    fn native_ar_recovers_ar2_direction() {
        // A trending series: forecasts should continue the trend rather
        // than snap back.
        let season = 8;
        let len = 200;
        let series: Vec<f64> = (0..len).map(|t| 100.0 + 0.5 * t as f64).collect();
        let mut f = NativeArForecaster::new(season, 4, 3);
        let out = f.forecast(&[series.clone()]);
        let last = series[len - 1];
        assert!(out[0][0] > last - 2.0, "forecast {:?} vs last {last}", out[0]);
    }

    #[test]
    fn native_ar_nonnegative() {
        let series = vec![vec![0.0; 800]];
        let mut f = NativeArForecaster::new(96, 8, 4);
        let out = f.forecast(&series);
        assert!(out[0].iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn short_history_falls_back_to_persistence() {
        let series = vec![vec![5.0; 20]];
        let mut f = NativeArForecaster::new(96, 8, 4);
        let out = f.forecast(&series);
        assert_eq!(out[0], vec![5.0; 4]);
    }

    #[test]
    fn solve_dense_identity() {
        let mut a = vec![1.0, 0.0, 0.0, 1.0];
        let mut b = vec![3.0, 4.0];
        let x = solve_dense(&mut a, &mut b, 2);
        assert_eq!(x, vec![3.0, 4.0]);
    }

    #[test]
    fn solve_dense_general() {
        // [[2,1],[1,3]] x = [5,10] → x = [1, 3].
        let mut a = vec![2.0, 1.0, 1.0, 3.0];
        let mut b = vec![5.0, 10.0];
        let x = solve_dense(&mut a, &mut b, 2);
        assert!((x[0] - 1.0).abs() < 1e-9 && (x[1] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn mape_basic() {
        assert!((mape(&[110.0], &[100.0]) - 0.1).abs() < 1e-9);
    }
}
