//! Performance model: SplitWise-style batch execution-time estimation
//! per (model, GPU) — the simulator's analogue of the interpolation model
//! the paper trains on real inference traces (§7.1, Fig 9).
//!
//! Two phases with distinct rooflines (per the SplitWise observation):
//! * **prefill** — compute-bound: time ≈ overhead + tokens / prompt_tps.
//! * **decode** — bandwidth-bound: per-iteration time grows with batch
//!   size (weights re-read amortizes) and KV residency.
//!
//! Profiles are anchored to the numbers the paper publishes: Llama2-70B
//! prompt TPS ≈ 21 000 on 8×H100 (Fig 9), instance input-TPS capacity
//! quartiles of §2.1 (Llama2-70B 95–522 on H100, 68–293 on A100; Bloom
//! 82–397 / 50–177), and A100 ≈ H100 / 1.8.  The MI300 class derates
//! compute by 1.45× but carries 1.5 TiB of HBM and a deeper batch cap —
//! the high-HBM/mid-throughput point on the §5 SKU axis, decisive for
//! KV-heavy models (Bloom-class) and long-context traffic.  The KV-cache
//! byte costs come from the published architectures
//! (layers × kv-heads × head-dim).

use crate::config::{GpuKind, ModelKind, Time};

/// Static per-(model, GPU) performance profile.
#[derive(Debug, Clone)]
pub struct PerfProfile {
    /// The model this profile describes.
    pub model: ModelKind,
    /// The GPU SKU this profile describes.
    pub gpu: GpuKind,
    /// Prompt-phase throughput, tokens/sec for a saturated batch.
    pub prompt_tps: f64,
    /// Fixed per-batch prefill overhead (scheduling + kernel launch), sec.
    pub prefill_overhead: Time,
    /// Decode iteration base time (batch of 1), sec.
    pub tbt_base: Time,
    /// Decode iteration increment per concurrent sequence, sec.
    pub tbt_per_seq: Time,
    /// Decode iteration increment per MiB of resident KV, sec (captures
    /// the bandwidth cost of attending over long contexts).
    pub tbt_per_kv_mib: Time,
    /// KV-cache bytes per token.
    pub kv_bytes_per_token: u64,
    /// Model weights resident size (GiB).
    pub weights_gib: f64,
    /// Max concurrent sequences (continuous-batching running cap) —
    /// per-SKU: the MI300 class runs a deeper cap because its 1.5 TiB
    /// of HBM keeps far more KV resident.
    pub max_batch: usize,
    /// Published input-TPS capacity anchor (§2.1 quartiles) — kept for
    /// reference/reporting; the ILP uses [`PerfProfile::input_tps_capacity`],
    /// which is derived from this same batch-time model so that the
    /// optimizer's instance counts match what the simulated instances can
    /// actually sustain.
    pub published_tps_anchor: f64,
}

/// Reference request *input* tokens used for capacity derivation (≈ the
/// trace means: RAG-heavy inputs, sub-1k outputs).
pub const REF_INPUT_TOKENS: u64 = 1_700;
/// Reference request *output* tokens (see [`REF_INPUT_TOKENS`]).
pub const REF_OUTPUT_TOKENS: u64 = 370;
/// Reference request total KV reservation, input + output rounded up to
/// the planning granularity (see [`REF_INPUT_TOKENS`]).
pub const REF_TOTAL_TOKENS: u64 = 3_000;

/// Fraction of saturation throughput an instance is *planned* at (the
/// queueing headroom that keeps p95 TTFT inside the SLA; ties the §5 θ to
/// the ~60–70% utilization operating point of §4/§6).
pub const CAPACITY_HEADROOM: f64 = 0.6;

impl PerfProfile {
    /// Look up the profile for a (model, GPU) pair.
    pub fn get(model: ModelKind, gpu: GpuKind) -> PerfProfile {
        // H100 anchors; A100 derates compute by 1.8× (paper's quartile
        // ratios) and capacity accordingly.
        let (prompt_tps, tbt_base, tbt_per_seq, kv_bytes, weights_gib, anchor) = match model {
            // 70 layers × 14336 hidden × 2 (K+V) × 2 bytes ≈ 4.0 MiB/token.
            ModelKind::Bloom176B => (9_000.0, 0.028, 0.0009, 4_014_080, 352.0, 397.0),
            // GQA: 80 layers × 8 kv-heads × 128 dim × 2 × 2 ≈ 320 KiB/token.
            ModelKind::Llama2_70B => (21_000.0, 0.020, 0.00055, 327_680, 140.0, 522.0),
            // 32 layers × 8 × 128 × 2 × 2 = 128 KiB/token.
            ModelKind::Llama31_8B => (120_000.0, 0.006, 0.00012, 131_072, 16.0, 3_000.0),
            // 28 layers × 8 × 128 × 2 × 2 = 112 KiB/token.
            ModelKind::Llama32_3B => (250_000.0, 0.004, 0.00008, 114_688, 6.0, 6_000.0),
            // MoE: 109B params / 17B active — prompt throughput like a
            // ~17B dense model, weights like a 109B one.
            ModelKind::Llama4Scout => (80_000.0, 0.009, 0.00018, 196_608, 218.0, 2_200.0),
            // The real PJRT-served model; profile measured by `serve`
            // (Fig 9 experiment) — placeholders refined at runtime.
            ModelKind::TinyLm => (40_000.0, 0.002, 0.0001, 16_384, 0.013, 10_000.0),
        };
        // Compute derates off the H100 anchors: A100 by 1.8x (paper's
        // quartile ratios); MI300-class by 1.45x (mid throughput).  The
        // MI300's distinguishing axis is HBM, not FLOPs: 1.5 TiB per VM
        // lets continuous batching hold a ~1.5x deeper running set, so
        // its batch cap rises while the per-iteration times derate.
        let (derate, max_batch) = match gpu {
            GpuKind::H100x8 => (1.0, 64),
            GpuKind::A100x8 => (1.8, 64),
            GpuKind::Mi300x8 => (1.45, 96),
        };
        PerfProfile {
            model,
            gpu,
            prompt_tps: prompt_tps / derate,
            prefill_overhead: 0.015 * derate,
            tbt_base: tbt_base * derate,
            tbt_per_seq: tbt_per_seq * derate,
            tbt_per_kv_mib: 2.0e-8 * derate,
            kv_bytes_per_token: kv_bytes,
            weights_gib,
            max_batch,
            published_tps_anchor: anchor / derate,
        }
    }

    /// The KV budget one instance *serves against* — the denominator of
    /// the paper's effective-memory-utilization signal.  It is the HBM
    /// capacity clipped to what the continuous-batching cap can actually
    /// occupy, so utilization ≈ batch occupancy for small-KV models (where
    /// compute saturates long before HBM) while staying genuinely
    /// memory-bound for Bloom-class KV footprints.
    pub fn serving_kv_budget(&self) -> u64 {
        self.kv_capacity_tokens()
            .min(self.max_batch as u64 * REF_TOTAL_TOKENS)
    }

    /// Concurrency at a full serving budget.
    pub fn max_concurrency(&self) -> usize {
        ((self.serving_kv_budget() / REF_TOTAL_TOKENS) as usize)
            .clamp(1, self.max_batch)
    }

    /// Saturation throughput in *input* TPS for the reference request mix
    /// (steady-state continuous batching at the full concurrency).
    pub fn saturation_input_tps(&self) -> f64 {
        let b = self.max_concurrency();
        // Average resident KV ≈ half the reservation over a request's life.
        let kv = b as u64 * REF_TOTAL_TOKENS / 2;
        let per_req = self.prefill_time(REF_INPUT_TOKENS)
            + REF_OUTPUT_TOKENS as f64 * self.decode_iter_time(b, kv);
        (b as f64 / per_req) * REF_INPUT_TOKENS as f64
    }

    /// θ of §5: the input TPS one instance is planned at — saturation
    /// derated by the SLA headroom.  Derived from the same batch-time
    /// model the simulator executes, so ILP allocations and simulated
    /// behaviour are self-consistent.
    pub fn input_tps_capacity(&self) -> f64 {
        CAPACITY_HEADROOM * self.saturation_input_tps()
    }

    /// KV capacity of one instance in tokens.
    pub fn kv_capacity_tokens(&self) -> u64 {
        let free_gib = (self.gpu.hbm_gib() - self.weights_gib).max(1.0);
        (free_gib * (1u64 << 30) as f64 / self.kv_bytes_per_token as f64) as u64
    }

    /// Prefill time for a batch with `tokens` total prompt tokens.
    pub fn prefill_time(&self, tokens: u64) -> Time {
        if tokens == 0 {
            return 0.0;
        }
        self.prefill_overhead + tokens as f64 / self.prompt_tps
    }

    /// One decode iteration for `batch` sequences with `kv_tokens` total
    /// resident KV tokens.
    pub fn decode_iter_time(&self, batch: usize, kv_tokens: u64) -> Time {
        if batch == 0 {
            return 0.0;
        }
        let kv_mib = kv_tokens as f64 * self.kv_bytes_per_token as f64 / (1u64 << 20) as f64;
        self.tbt_base + self.tbt_per_seq * batch as f64 + self.tbt_per_kv_mib * kv_mib
    }

    /// Analytic end-to-end estimate for a single request at a given batch
    /// level (used by tests and the Fig 9 fidelity study).
    pub fn request_time(&self, input: u32, output: u32, batch: usize, kv_tokens: u64) -> Time {
        self.prefill_time(input as u64)
            + output as f64 * self.decode_iter_time(batch.max(1), kv_tokens)
    }

    /// Interconnect bandwidth available for prefill→decode KV-cache
    /// migration, bytes/sec per SKU (NVLink/IB-class fabrics; the H100
    /// generation ships the fastest links, the A100 half that, the MI300
    /// class in between).
    pub fn kv_transfer_bytes_per_sec(&self) -> f64 {
        match self.gpu {
            GpuKind::H100x8 => 50.0e9,
            GpuKind::A100x8 => 25.0e9,
            GpuKind::Mi300x8 => 40.0e9,
        }
    }

    /// Time to migrate a request's prompt KV cache from a prefill
    /// instance to a decode instance: a fixed per-transfer setup plus
    /// `tokens × kv_bytes_per_token` over the SKU's migration bandwidth.
    /// This is the explicit disaggregation tax — the router minimizes it
    /// when placing decode work, and the metrics layer accounts every
    /// second of it under `kv_transfer_secs`.
    pub fn kv_transfer_time(&self, tokens: u64) -> Time {
        KV_TRANSFER_SETUP
            + tokens as f64 * self.kv_bytes_per_token as f64 / self.kv_transfer_bytes_per_sec()
    }

    /// θ for a **prefill-only** instance under a TTFT target, in input
    /// TPS.  Prefill is compute-bound and effectively serial per batch,
    /// so the raw rate is `REF_INPUT / prefill_time(REF_INPUT)`; the
    /// sustainable utilization is gated by queueing: keeping the wait
    /// under the TTFT budget needs `ρ ≤ 1 − service/target` (the M/D/1
    /// wait `service·ρ/(1−ρ)` stays under `target − service` there),
    /// clamped into `[0.1, CAPACITY_HEADROOM]` so θ never exceeds the
    /// fleet-wide planning headroom and never degenerates to zero.
    pub fn prefill_input_tps_capacity(&self, ttft_target: Time) -> f64 {
        let service = self.prefill_time(REF_INPUT_TOKENS);
        let rho = (1.0 - service / ttft_target.max(service)).clamp(0.1, CAPACITY_HEADROOM);
        rho * REF_INPUT_TOKENS as f64 / service
    }

    /// θ for a **decode-only** instance under an ITL target, expressed in
    /// *input-equivalent* TPS (the §5 demand currency).  The ITL target
    /// caps the continuous-batching depth — the largest `b` whose
    /// iteration time stays inside the target at reference KV residency —
    /// and the resulting output token rate converts to input TPS via the
    /// reference mix, derated by the planning headroom.
    pub fn decode_input_tps_capacity(&self, itl_target: Time) -> f64 {
        let kv_mib_per_seq = (REF_TOTAL_TOKENS / 2) as f64 * self.kv_bytes_per_token as f64
            / (1u64 << 20) as f64;
        let per_seq = self.tbt_per_seq + self.tbt_per_kv_mib * kv_mib_per_seq;
        let b = if itl_target > self.tbt_base + per_seq {
            ((itl_target - self.tbt_base) / per_seq) as usize
        } else {
            1
        };
        let b = b.clamp(1, self.max_concurrency());
        let iter = self.decode_iter_time(b, b as u64 * REF_TOTAL_TOKENS / 2);
        let out_tps = b as f64 / iter;
        CAPACITY_HEADROOM * out_tps * REF_INPUT_TOKENS as f64 / REF_OUTPUT_TOKENS as f64
    }
}

/// Fixed per-transfer setup cost of a KV-cache migration (connection +
/// layout negotiation), sec.
pub const KV_TRANSFER_SETUP: Time = 0.002;

/// Profile table for a simulation run: one [`PerfProfile`] per
/// (model, GPU SKU) pair in the fleet.  The §5 formulation is per-SKU
/// (θ_{i,k}, α_k), so the table carries every SKU the cluster may
/// provision; single-SKU runs are the degenerate one-column case.
#[derive(Debug, Clone)]
pub struct PerfTable {
    gpus: Vec<GpuKind>,
    models: Vec<ModelKind>,
    profiles: Vec<PerfProfile>,
    /// `lookup[model.index()][gpu.index()]` → slot in `profiles` (O(1)
    /// hot-path lookup, mirroring `EndpointMap`).
    lookup: [[Option<u8>; GpuKind::COUNT]; 6],
}

impl PerfTable {
    /// Single-SKU table (the pre-heterogeneity construction).
    pub fn new(gpu: GpuKind, models: &[ModelKind]) -> Self {
        Self::for_fleet(&[gpu], models)
    }

    /// Table covering every (model, SKU) pair of a fleet.
    pub fn for_fleet(gpus: &[GpuKind], models: &[ModelKind]) -> Self {
        assert!(!gpus.is_empty(), "fleet needs at least one GPU SKU");
        let mut t = PerfTable {
            gpus: Vec::with_capacity(gpus.len()),
            models: models.to_vec(),
            profiles: Vec::with_capacity(models.len() * gpus.len()),
            lookup: [[None; GpuKind::COUNT]; 6],
        };
        for &g in gpus {
            if !t.gpus.contains(&g) {
                t.gpus.push(g);
            }
        }
        for &m in models {
            for gi in 0..t.gpus.len() {
                let g = t.gpus[gi];
                debug_assert!(t.profiles.len() < u8::MAX as usize);
                t.lookup[m.index()][g.index()] = Some(t.profiles.len() as u8);
                t.profiles.push(PerfProfile::get(m, g));
            }
        }
        t
    }

    /// The profile for a (model, SKU) pair — O(1) via the dense slot
    /// grid.  Panics if the pair is not in this table's fleet.
    pub fn profile(&self, model: ModelKind, gpu: GpuKind) -> &PerfProfile {
        match self.lookup[model.index()][gpu.index()] {
            Some(s) => &self.profiles[s as usize],
            None => panic!("no profile for {model} on {gpu}"),
        }
    }

    /// The fleet's SKUs, fleet order (the dense axis the controller's
    /// per-SKU vectors align with).
    pub fn gpus(&self) -> &[GpuKind] {
        &self.gpus
    }

    /// The first SKU — what single-SKU call sites mean by "the GPU".
    pub fn primary_gpu(&self) -> GpuKind {
        self.gpus[0]
    }

    /// The models this table profiles, construction order.
    pub fn models(&self) -> impl Iterator<Item = ModelKind> + '_ {
        self.models.iter().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama2_h100_prompt_tps_matches_fig9() {
        let p = PerfProfile::get(ModelKind::Llama2_70B, GpuKind::H100x8);
        assert_eq!(p.prompt_tps, 21_000.0);
        // 21k tokens of prompt ≈ 1 s + overhead.
        let t = p.prefill_time(21_000);
        assert!((t - 1.015).abs() < 1e-9);
    }

    #[test]
    fn a100_derates_by_1_8() {
        let h = PerfProfile::get(ModelKind::Llama2_70B, GpuKind::H100x8);
        let a = PerfProfile::get(ModelKind::Llama2_70B, GpuKind::A100x8);
        assert!((h.prompt_tps / a.prompt_tps - 1.8).abs() < 1e-9);
        assert!(a.input_tps_capacity() < h.input_tps_capacity());
        // Paper anchors: Llama2-70B Q3 ≈ 293 on A100 vs 522 on H100.
        assert!((a.published_tps_anchor - 290.0).abs() < 10.0);
    }

    #[test]
    fn derived_capacity_consistent_with_batch_model() {
        // θ must equal headroom × saturation, and the instance must be
        // able to serve θ with slack: serving the reference mix at θ
        // implies concurrency below the max.
        let p = PerfProfile::get(ModelKind::Llama2_70B, GpuKind::H100x8);
        let theta = p.input_tps_capacity();
        let sat = p.saturation_input_tps();
        assert!((theta / sat - CAPACITY_HEADROOM).abs() < 1e-12);
        assert!(theta > 100.0, "theta {theta}");
        // Bloom is memory-bound: its budget is HBM-limited.
        let b = PerfProfile::get(ModelKind::Bloom176B, GpuKind::A100x8);
        assert!(b.serving_kv_budget() == b.kv_capacity_tokens());
        // Llama's serving budget is batch-cap-limited, not HBM-limited.
        assert!(p.serving_kv_budget() < p.kv_capacity_tokens());
    }

    #[test]
    fn decode_time_grows_with_batch_and_kv() {
        let p = PerfProfile::get(ModelKind::Llama2_70B, GpuKind::H100x8);
        let t1 = p.decode_iter_time(1, 1_000);
        let t32 = p.decode_iter_time(32, 1_000);
        let t32kv = p.decode_iter_time(32, 1_000_000);
        assert!(t1 < t32 && t32 < t32kv);
    }

    /// The phase-split bracketing property the disaggregated pipeline
    /// relies on, checked per (model, SKU, batch): unified E2E for the
    /// reference request is at least the slowest single phase and at most
    /// the phase sum plus the KV-transfer tax.
    #[test]
    fn phase_split_brackets_unified_e2e() {
        for m in ModelKind::EVAL5 {
            for g in GpuKind::ALL {
                let p = PerfProfile::get(m, g);
                for b in [1usize, 8, 32] {
                    let b = b.min(p.max_concurrency());
                    let kv = b as u64 * REF_TOTAL_TOKENS / 2;
                    let prefill = p.prefill_time(REF_INPUT_TOKENS);
                    let decode = REF_OUTPUT_TOKENS as f64 * p.decode_iter_time(b, kv);
                    let unified =
                        p.request_time(REF_INPUT_TOKENS as u32, REF_OUTPUT_TOKENS as u32, b, kv);
                    let transfer = p.kv_transfer_time(REF_INPUT_TOKENS);
                    assert!(transfer > 0.0, "{m} on {g}: transfer {transfer}");
                    assert!(
                        unified >= prefill.max(decode) - 1e-12,
                        "{m} on {g} b={b}: unified {unified} < max phase {}",
                        prefill.max(decode)
                    );
                    assert!(
                        unified <= prefill + decode + transfer + 1e-12,
                        "{m} on {g} b={b}: unified {unified} > split {}",
                        prefill + decode + transfer
                    );
                }
            }
        }
    }

    /// Per-phase θ: positive everywhere, weakly monotone in the SLO
    /// target (tighter targets never buy throughput), and the transfer
    /// model orders SKUs by link speed.
    #[test]
    fn phase_capacities_positive_and_monotone_in_targets() {
        for m in ModelKind::EVAL5 {
            for g in GpuKind::ALL {
                let p = PerfProfile::get(m, g);
                let tp_loose = p.prefill_input_tps_capacity(1.0);
                let tp_tight = p.prefill_input_tps_capacity(0.12);
                assert!(tp_tight > 0.0, "{m} on {g}");
                assert!(tp_tight <= tp_loose + 1e-9, "{m} on {g}: {tp_tight} > {tp_loose}");
                let td_loose = p.decode_input_tps_capacity(0.2);
                let td_tight = p.decode_input_tps_capacity(0.05);
                assert!(td_tight > 0.0, "{m} on {g}");
                assert!(td_tight <= td_loose + 1e-9, "{m} on {g}: {td_tight} > {td_loose}");
                // Transfer time grows with tokens.
                assert!(p.kv_transfer_time(10_000) > p.kv_transfer_time(100));
            }
        }
        // Faster links transfer the same KV strictly faster.
        let h = PerfProfile::get(ModelKind::Llama2_70B, GpuKind::H100x8);
        let a = PerfProfile::get(ModelKind::Llama2_70B, GpuKind::A100x8);
        assert!(h.kv_transfer_time(50_000) < a.kv_transfer_time(50_000));
    }

    #[test]
    fn bloom_kv_heavier_than_llama() {
        let b = PerfProfile::get(ModelKind::Bloom176B, GpuKind::A100x8);
        let l = PerfProfile::get(ModelKind::Llama2_70B, GpuKind::A100x8);
        assert!(b.kv_bytes_per_token > 10 * l.kv_bytes_per_token);
        assert!(b.kv_capacity_tokens() < l.kv_capacity_tokens());
    }

    #[test]
    fn kv_capacity_positive_for_all_pairs() {
        for m in ModelKind::EVAL5 {
            for g in GpuKind::ALL {
                let p = PerfProfile::get(m, g);
                assert!(p.kv_capacity_tokens() > 10_000, "{m} on {g}");
            }
        }
    }

    #[test]
    fn mi300_is_high_hbm_mid_throughput() {
        let h = PerfProfile::get(ModelKind::Llama2_70B, GpuKind::H100x8);
        let a = PerfProfile::get(ModelKind::Llama2_70B, GpuKind::A100x8);
        let m = PerfProfile::get(ModelKind::Llama2_70B, GpuKind::Mi300x8);
        // Mid throughput: between the H100 and A100 derates.
        assert!(m.prompt_tps < h.prompt_tps && m.prompt_tps > a.prompt_tps);
        assert!(m.tbt_base > h.tbt_base && m.tbt_base < a.tbt_base);
        // High HBM: deeper batch cap and a larger serving budget.
        assert!(m.max_batch > h.max_batch);
        assert!(m.serving_kv_budget() > h.serving_kv_budget());
        assert!(m.kv_capacity_tokens() > 2 * h.kv_capacity_tokens());
        // A100 keeps the best $-per-θ for compute-bound Llama2 (the ILP
        // ordering the 2-SKU tests rely on must survive k=3).
        let per_theta = |p: &PerfProfile| p.gpu.dollars_per_hour() / p.input_tps_capacity();
        assert!(per_theta(&a) < per_theta(&m), "A100 {} MI300 {}", per_theta(&a), per_theta(&m));
        assert!(per_theta(&a) < per_theta(&h));
    }

    #[test]
    fn mi300_dominates_for_kv_bound_bloom() {
        // Bloom's 4 MiB/token KV makes the NVIDIA SKUs HBM-bound; the
        // MI300's 1.5 TiB flips the economics: more concurrency, and a
        // better $-per-θ than either 640 GiB SKU.
        let h = PerfProfile::get(ModelKind::Bloom176B, GpuKind::H100x8);
        let m = PerfProfile::get(ModelKind::Bloom176B, GpuKind::Mi300x8);
        assert!(m.max_concurrency() > 2 * h.max_concurrency());
        let per_theta = |p: &PerfProfile| p.gpu.dollars_per_hour() / p.input_tps_capacity();
        assert!(per_theta(&m) < per_theta(&h));
    }

    #[test]
    fn zero_cases() {
        let p = PerfProfile::get(ModelKind::Llama31_8B, GpuKind::H100x8);
        assert_eq!(p.prefill_time(0), 0.0);
        assert_eq!(p.decode_iter_time(0, 0), 0.0);
    }

    #[test]
    fn table_lookup() {
        let t = PerfTable::new(GpuKind::H100x8, &ModelKind::EVAL4);
        let p = t.profile(ModelKind::Bloom176B, GpuKind::H100x8);
        assert_eq!(p.model, ModelKind::Bloom176B);
        assert_eq!(p.gpu, GpuKind::H100x8);
        assert_eq!(t.models().count(), 4);
        assert_eq!(t.gpus(), &[GpuKind::H100x8]);
        assert_eq!(t.primary_gpu(), GpuKind::H100x8);
    }

    #[test]
    fn fleet_table_covers_every_pair() {
        // The full k=3 fleet: every (model, SKU) pair gets a profile.
        let t = PerfTable::for_fleet(&GpuKind::ALL, &ModelKind::EVAL4);
        assert_eq!(t.gpus(), &GpuKind::ALL);
        for m in ModelKind::EVAL4 {
            for g in GpuKind::ALL {
                let p = t.profile(m, g);
                assert_eq!((p.model, p.gpu), (m, g));
            }
        }
        // Per-SKU profiles differ (A100/MI300 derated) — the ILP's θ_{i,k}.
        let h = t.profile(ModelKind::Llama2_70B, GpuKind::H100x8);
        let a = t.profile(ModelKind::Llama2_70B, GpuKind::A100x8);
        let m = t.profile(ModelKind::Llama2_70B, GpuKind::Mi300x8);
        assert!(h.input_tps_capacity() > a.input_tps_capacity());
        assert!(m.input_tps_capacity() > a.input_tps_capacity());
    }

    #[test]
    #[should_panic(expected = "no profile")]
    fn missing_pair_panics() {
        let t = PerfTable::new(GpuKind::H100x8, &ModelKind::EVAL4);
        let _ = t.profile(ModelKind::Llama2_70B, GpuKind::A100x8);
    }
}
