"""Batched iterated AR(p) forecast as a Pallas kernel (Layer 1).

SageServe's Load Predictor forecasts the next hour of input TPS for every
(model, region) pair — S = l·r series — each hour.  The hot loop is an
iterated AR recursion: every horizon step consumes the previous step's
prediction, so the H steps are inherently sequential while the S series are
embarrassingly parallel.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the *series*
axis; each grid step holds a ``[block_s, p]`` tile of history and
coefficients resident in VMEM and runs the whole H-step recursion in-kernel
with a ``fori_loop``, writing the ``[block_s, H]`` forecast tile once.
History is loaded from HBM exactly once and the recursion never round-trips
through HBM — the entire working set is a few KiB of VMEM.  The lag shift
is expressed as a roll + masked insert on the VPU (8×128 lanes), which
vectorizes across the series tile.

Executed with ``interpret=True`` for CPU-PJRT portability.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_S = 128


def _ar_kernel(hist_ref, coef_ref, icept_ref, out_ref, *, horizon: int):
    """One series-tile grid step: run the full H-step AR recursion.

    hist_ref:  [block_s, p]  newest-last history tile
    coef_ref:  [block_s, p]  coefs, coef[:, 0] multiplies the newest lag
    icept_ref: [block_s, 1]  per-series intercept
    out_ref:   [block_s, horizon]
    """
    block_s, p = hist_ref.shape
    # lags[:, 0] = newest observation (reverse the newest-last layout).
    lags = hist_ref[...][:, ::-1].astype(jnp.float32)
    coefs = coef_ref[...].astype(jnp.float32)
    icept = icept_ref[...][:, 0].astype(jnp.float32)

    def step(h, carry):
        lags = carry
        nxt = icept + jnp.sum(coefs * lags, axis=1)
        out_ref[:, h] = nxt.astype(out_ref.dtype)
        # Shift the lag window: drop the oldest, insert the prediction at
        # lane 0.  roll+where keeps this a pure VPU op (no gathers).
        rolled = jnp.roll(lags, shift=1, axis=1)
        lane = jax.lax.broadcasted_iota(jnp.int32, (block_s, p), 1)
        return jnp.where(lane == 0, nxt[:, None], rolled)

    jax.lax.fori_loop(0, horizon, step, lags)


@functools.partial(jax.jit, static_argnames=("horizon", "block_s"))
def ar_forecast(history: jnp.ndarray, coefs: jnp.ndarray,
                intercept: jnp.ndarray, *, horizon: int,
                block_s: int = DEFAULT_BLOCK_S) -> jnp.ndarray:
    """Forecast ``horizon`` steps for a batch of AR(p) series.

    Semantics match :func:`..kernels.ref.ar_forecast_ref` exactly.

    Args:
      history: ``[series, p]`` most-recent observations, newest last.
      coefs: ``[series, p]`` AR coefficients, index 0 = newest lag.
      intercept: ``[series]`` constants.
      horizon: forecast steps H (static).
      block_s: series-tile size (static); clamped and padded internally.

    Returns:
      ``[series, horizon]`` float32 forecasts.
    """
    series, p = history.shape
    if coefs.shape != (series, p):
        raise ValueError(f"coefs {coefs.shape} != history {history.shape}")
    if intercept.shape != (series,):
        raise ValueError(f"intercept {intercept.shape} != ({series},)")
    bs = min(block_s, series)
    # Pad the series axis up to a tile multiple; padded rows compute
    # garbage that is sliced away below.
    padded = (series + bs - 1) // bs * bs
    if padded != series:
        pad = padded - series
        history = jnp.pad(history, ((0, pad), (0, 0)))
        coefs = jnp.pad(coefs, ((0, pad), (0, 0)))
        intercept = jnp.pad(intercept, ((0, pad),))

    grid = (padded // bs,)
    out = pl.pallas_call(
        functools.partial(_ar_kernel, horizon=horizon),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, p), lambda i: (i, 0)),
            pl.BlockSpec((bs, p), lambda i: (i, 0)),
            pl.BlockSpec((bs, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bs, horizon), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, horizon), jnp.float32),
        interpret=True,  # CPU-PJRT portability; see module docstring.
    )(history, coefs, intercept[:, None])
    return out[:series]
