"""Tiled online-softmax attention as a Pallas kernel (Layer 1).

GPU flash-attention assigns one threadblock per query tile and streams K/V
tiles through shared memory.  The TPU rethink here (see DESIGN.md
§Hardware-Adaptation): the grid is (head, q_block); each grid step holds a
Q tile resident in VMEM via its ``BlockSpec`` and loops over K/V tiles,
accumulating the online-softmax statistics (running max ``m``, running
normalizer ``l``, un-normalized output ``acc``).  The two matmuls per inner
step (``q @ k^T`` and ``p @ v``) are 128-aligned so the MXU systolic array
runs them at full tile occupancy on real hardware; on this CPU image the
kernel executes under ``interpret=True`` so the lowered HLO is portable to
the PJRT CPU client.

VMEM budget per grid step at (Bq=128, Bk=128, d=256, f32):
Q 128·256·4 = 128 KiB, K/V 2·128·256·4 = 256 KiB, acc 128 KiB, m/l 1 KiB —
≈ 0.5 MiB total, leaving >15 MiB of VMEM for double-buffering the K/V
stream (handled by the Pallas pipeline on TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 128 matches both the MXU systolic dimension and the
# VPU lane count, so these should only shrink for tiny toy shapes.
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

_NEG_INF = float("-inf")


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, seq_k: int,
                      block_k: int, causal: bool, q_offset_blocks: int):
    """One (head, q_block) grid step: stream K/V tiles with online softmax.

    Refs arrive pre-tiled by BlockSpec:
      q_ref: [block_q, d]   — this step's Q tile (VMEM resident)
      k_ref: [seq_k, d]     — full K for this head (streamed below)
      v_ref: [seq_k, d]     — full V for this head
      o_ref: [block_q, d]   — output tile
    """
    block_q, d = q_ref.shape
    scale = 1.0 / (d ** 0.5)
    q = q_ref[...].astype(jnp.float32) * scale

    q_block_idx = pl.program_id(1)
    # Global row index of the first query in this tile, shifted so the
    # causal diagonal sits at the end of the key axis (decode-friendly).
    q_start = (q_block_idx + q_offset_blocks) * block_q

    num_k_blocks = pl.cdiv(seq_k, block_k)

    def body(kb, carry):
        acc, m_prev, l_prev = carry
        k_tile = k_ref[pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        v_tile = v_ref[pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        # [block_q, block_k] logits on the MXU.
        s = q @ k_tile.T
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            cols = kb * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                           (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, _NEG_INF)
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        # exp with a fully-masked-row guard: if m_new is -inf the row has
        # seen no valid key yet; keep the accumulator at zero.
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - safe_m[:, None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + p @ v_tile
        return acc_new, m_new, l_new

    init = (jnp.zeros((block_q, d), jnp.float32),
            jnp.full((block_q,), _NEG_INF, jnp.float32),
            jnp.zeros((block_q,), jnp.float32))
    acc, _m, l = jax.lax.fori_loop(0, num_k_blocks, body, init)
    # Rows with l == 0 (no visible keys — cannot happen for causal decode
    # with offset, but keep the kernel total) emit zeros rather than NaN.
    l_safe = jnp.where(l > 0.0, l, 1.0)
    o_ref[...] = (acc / l_safe[:, None]).astype(o_ref.dtype)


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, seq_k: int,
                   block_k: int):
    """Decode-path grid step: one query row against a fixed-size KV buffer.

    Only key slots ``col < len_ref[0]`` are valid (the cache buffer beyond
    the sequence's current length holds garbage).  Same online-softmax
    structure as the prefill kernel, masking on the *valid length* instead
    of the causal diagonal.

      q_ref: [1, d]        this head's single query row
      k_ref: [seq_k, d]    full KV buffer for this head
      v_ref: [seq_k, d]
      len_ref: [1]         valid KV length for this head (int32)
      o_ref: [1, d]
    """
    _, d = q_ref.shape
    scale = 1.0 / (d ** 0.5)
    q = q_ref[...].astype(jnp.float32) * scale
    kv_len = len_ref[0]
    num_k_blocks = pl.cdiv(seq_k, block_k)

    def body(kb, carry):
        acc, m_prev, l_prev = carry
        k_tile = k_ref[pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        v_tile = v_ref[pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        s = q @ k_tile.T  # [1, block_k]
        cols = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        s = jnp.where(cols < kv_len, s, _NEG_INF)
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - safe_m[:, None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + p @ v_tile
        return acc_new, m_new, l_new

    init = (jnp.zeros((1, d), jnp.float32),
            jnp.full((1,), _NEG_INF, jnp.float32),
            jnp.zeros((1,), jnp.float32))
    acc, _m, l = jax.lax.fori_loop(0, num_k_blocks, body, init)
    l_safe = jnp.where(l > 0.0, l, 1.0)
    o_ref[...] = (acc / l_safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k",))
def mha_attention_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         kv_len: jnp.ndarray, *,
                         block_k: int = DEFAULT_BLOCK_K) -> jnp.ndarray:
    """Single-step decode attention over fixed-size KV cache buffers.

    Args:
      q: ``[heads, 1, head_dim]`` — one new query row per head.
      k, v: ``[heads, max_len, head_dim]`` cache buffers; slots at or past
        ``kv_len[h]`` are ignored.
      kv_len: ``[heads]`` int32 valid lengths (the new token's position + 1).
      block_k: KV streaming tile size.

    Returns:
      ``[heads, 1, head_dim]`` attention output.
    """
    heads, one, d = q.shape
    if one != 1:
        raise ValueError("decode kernel expects seq_q == 1")
    _, seq_k, _ = k.shape
    bk = min(block_k, seq_k)
    if seq_k % bk != 0:
        raise ValueError(f"max_len={seq_k} not divisible by block_k={bk}")
    kernel = functools.partial(_decode_kernel, seq_k=seq_k, block_k=bk)
    return pl.pallas_call(
        kernel,
        grid=(heads,),
        in_specs=[
            pl.BlockSpec((None, 1, d), lambda h: (h, 0, 0)),
            pl.BlockSpec((None, seq_k, d), lambda h: (h, 0, 0)),
            pl.BlockSpec((None, seq_k, d), lambda h: (h, 0, 0)),
            pl.BlockSpec((None, 1), lambda h: (h, 0)),
        ],
        out_specs=pl.BlockSpec((None, 1, d), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((heads, 1, d), q.dtype),
        interpret=True,  # CPU-PJRT portability; see module docstring.
    )(q, k, v, kv_len.astype(jnp.int32)[:, None])


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def mha_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  *, causal: bool = True,
                  block_q: int = DEFAULT_BLOCK_Q,
                  block_k: int = DEFAULT_BLOCK_K) -> jnp.ndarray:
    """Multi-head attention via the tiled Pallas kernel.

    Args:
      q: ``[heads, seq_q, head_dim]``.
      k, v: ``[heads, seq_k, head_dim]`` with ``seq_k >= seq_q``.
      causal: apply a causal mask whose diagonal is aligned to the end of
        the key axis (so ``seq_q == 1`` decodes attend to the whole prefix).
      block_q / block_k: VMEM tile sizes; clamped to the actual extents.

    Returns:
      ``[heads, seq_q, head_dim]`` attention output, dtype of ``q``.
    """
    heads, seq_q, d = q.shape
    _, seq_k, _ = k.shape
    bq = min(block_q, seq_q)
    bk = min(block_k, seq_k)
    if seq_q % bq != 0:
        raise ValueError(f"seq_q={seq_q} not divisible by block_q={bq}")
    if seq_k % bk != 0:
        raise ValueError(f"seq_k={seq_k} not divisible by block_k={bk}")
    # Causal-diagonal shift, in whole q-blocks (seq_k - seq_q must divide bq
    # for the in-kernel index math; true for our prefill/decode shapes).
    offset = seq_k - seq_q
    if causal and offset % bq != 0:
        raise ValueError(f"seq_k-seq_q={offset} not divisible by block_q={bq}")

    grid = (heads, seq_q // bq)
    kernel = functools.partial(_attention_kernel, seq_k=seq_k, block_k=bk,
                               causal=causal, q_offset_blocks=offset // bq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, seq_k, d), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((None, seq_k, d), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((heads, seq_q, d), q.dtype),
        interpret=True,  # CPU-PJRT portability; see module docstring.
    )(q, k, v)
