"""Layer-1 Pallas kernels for the SageServe reproduction.

Two compute hot-spots live here:

* :mod:`attention` — a tiled, online-softmax attention kernel (the TPU
  rethink of GPU flash-attention) used by the Layer-2 transformer that the
  Rust coordinator serves via PJRT.
* :mod:`ar_forecast` — a batched seasonal-AR forecast recursion used by the
  Layer-2 forecast graph that drives SageServe's predictive autoscaler.

Both are authored with ``interpret=True`` so the lowered HLO runs on the CPU
PJRT client (real-TPU lowering emits Mosaic custom-calls the CPU plugin
cannot execute).  :mod:`ref` holds the pure-``jnp`` oracles that pytest
checks the kernels against.
"""

from . import ref  # noqa: F401
from .attention import mha_attention, mha_attention_decode  # noqa: F401
from .ar_forecast import ar_forecast  # noqa: F401
