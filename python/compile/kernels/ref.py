"""Pure-``jnp`` oracles for the Layer-1 Pallas kernels.

These are the CORE correctness signal: pytest (and hypothesis sweeps)
assert that every Pallas kernel matches these reference implementations to
tight tolerances across shapes and dtypes.  They are deliberately written
in the most obvious way possible — no tiling, no online softmax, no
recursion tricks — so a reviewer can audit them against the math directly.
"""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  *, causal: bool = True) -> jnp.ndarray:
    """Plain softmax attention.

    Args:
      q: ``[heads, seq_q, head_dim]`` queries.
      k: ``[heads, seq_k, head_dim]`` keys.
      v: ``[heads, seq_k, head_dim]`` values.
      causal: mask out positions ``j > i`` when True.

    Returns:
      ``[heads, seq_q, head_dim]`` attention output.
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    logits = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    if causal:
        seq_q, seq_k = logits.shape[-2], logits.shape[-1]
        # Align the causal diagonal to the *end* of the key axis so a
        # single decode query (seq_q=1) attends to the full prefix.
        offset = seq_k - seq_q
        mask = jnp.tril(jnp.ones((seq_q, seq_k), bool), k=offset)
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", probs, v)


def attention_decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         kv_len: jnp.ndarray) -> jnp.ndarray:
    """Oracle for single-step decode attention over fixed KV buffers.

    Args:
      q: ``[heads, 1, head_dim]``.
      k, v: ``[heads, max_len, head_dim]``; slots at or past ``kv_len[h]``
        are invalid and must receive zero attention weight.
      kv_len: ``[heads]`` int32 valid lengths.
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    logits = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    cols = jnp.arange(k.shape[1])[None, None, :]
    mask = cols < kv_len[:, None, None]
    logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", probs, v)


def ar_forecast_ref(history: jnp.ndarray, coefs: jnp.ndarray,
                    intercept: jnp.ndarray, horizon: int) -> jnp.ndarray:
    """Iterated multi-step AR(p) forecast for a batch of series.

    For each series ``s`` the model is::

        y[t] = intercept[s] + sum_i coefs[s, i] * y[t - 1 - i]

    and forecasts beyond the history feed back their own predictions
    (classic iterated/plug-in multi-step AR).

    Args:
      history: ``[series, p]`` most-recent observations, **newest last**
        (``history[:, -1]`` is y[t-1]).
      coefs: ``[series, p]`` AR coefficients, ``coefs[:, 0]`` multiplies the
        newest lag y[t-1].
      intercept: ``[series]`` per-series constant.
      horizon: number of future steps H.

    Returns:
      ``[series, horizon]`` forecasts.
    """
    series, p = history.shape
    assert coefs.shape == (series, p)
    # lags[:, 0] = newest observation
    lags = history[:, ::-1]
    outs = []
    for _ in range(horizon):
        nxt = intercept + jnp.sum(coefs * lags, axis=1)
        outs.append(nxt)
        lags = jnp.concatenate([nxt[:, None], lags[:, :-1]], axis=1)
    return jnp.stack(outs, axis=1)


def layernorm_ref(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
                  eps: float = 1e-5) -> jnp.ndarray:
    """LayerNorm over the last axis (oracle for the L2 transformer)."""
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta
