"""AOT export: lower the Layer-2 graphs to HLO *text* artifacts.

Python runs exactly once, at build time (`make artifacts`); the Rust
coordinator loads these artifacts through the `xla` crate
(``HloModuleProto::from_text_file`` → ``PjRtClient::compile``) and never
touches Python again.

HLO **text** — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids that the pinned
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written to ``--out-dir`` (default ``../artifacts``):

  forecast.hlo.txt        [S, T] history        -> [S, H] TPS forecast
  tinylm_prefill.hlo.txt  (params…, tokens[B,S]) -> (logits, k_cache, v_cache)
  tinylm_decode.hlo.txt   (params…, token[B], pos[B], caches) -> (logits, caches)
  tinylm_params.bin       all parameters, flat little-endian f32, manifest order
  manifest.json           shapes/orders/config for the Rust loader
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_mod
from . import forecast_graph as fc_mod
from .model import ModelConfig
from .forecast_graph import ForecastConfig


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_forecast(out_dir: str, cfg: ForecastConfig) -> dict:
    spec = jax.ShapeDtypeStruct((cfg.n_series, cfg.history), jnp.float32)
    lowered = jax.jit(lambda h: (fc_mod.forecast(h, cfg),)).lower(spec)
    path = os.path.join(out_dir, "forecast.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"wrote {path}")
    return {
        "n_series": cfg.n_series, "history": cfg.history,
        "season": cfg.season, "order": cfg.order, "horizon": cfg.horizon,
    }


def export_tinylm_shape_variants(out_dir: str, base: ModelConfig) -> list:
    """Smaller (prefill_len, max_len) variants of the same weights.

    The Fig 9 fidelity study needs execution time to *vary* with shape —
    a single fixed-shape executable has constant cost regardless of the
    actual token count.  Weights are shared with the base export (the
    pos-embedding table is simply indexed below the variant's max_len),
    so only the HLO differs.
    """
    variants = [(32, 64), (64, 128)]  # base (128, 256) is the third point
    pspec = model_mod.params_spec(base)
    out = []
    for (s, m) in variants:
        cfg = dataclasses.replace(base, prefill_len=s, max_len=m)
        tok_spec = jax.ShapeDtypeStruct((cfg.batch, s), jnp.int32)
        lowered = jax.jit(
            lambda p, t, c=cfg: model_mod.prefill(p, t, c)
        ).lower(pspec, tok_spec)
        path = os.path.join(out_dir, f"tinylm_prefill_s{s}_m{m}.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        print(f"wrote {path}")

        bh = cfg.batch * cfg.n_heads
        cache_spec = jax.ShapeDtypeStruct(
            (cfg.n_layers, bh, m, cfg.head_dim), jnp.float32)
        tok1 = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)
        pos1 = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)
        lowered = jax.jit(
            lambda p, t, x, kc, vc, c=cfg: model_mod.decode_step(p, t, x, kc, vc, c)
        ).lower(pspec, tok1, pos1, cache_spec, cache_spec)
        path = os.path.join(out_dir, f"tinylm_decode_s{s}_m{m}.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        print(f"wrote {path}")
        out.append({"prefill_len": s, "max_len": m})
    return out


def export_tinylm(out_dir: str, cfg: ModelConfig, seed: int) -> dict:
    params = model_mod.init_params(cfg, seed=seed)
    pspec = model_mod.params_spec(cfg)

    # --- weights blob (manifest order = param_shapes order) ---
    blob_path = os.path.join(out_dir, "tinylm_params.bin")
    with open(blob_path, "wb") as f:
        for name, _ in model_mod.param_shapes(cfg):
            np.asarray(params[name], dtype="<f4").tofile(f)
    print(f"wrote {blob_path}")

    # NOTE on argument order: jax flattens the params dict by sorted key
    # order.  The Rust loader replays the same flattening (manifest stores
    # the *sorted* traversal order explicitly as `hlo_param_order`).
    sorted_names = sorted(p[0] for p in model_mod.param_shapes(cfg))

    # --- prefill ---
    tok_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.prefill_len), jnp.int32)
    lowered = jax.jit(
        lambda p, t: model_mod.prefill(p, t, cfg)
    ).lower(pspec, tok_spec)
    path = os.path.join(out_dir, "tinylm_prefill.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"wrote {path}")

    # --- decode step ---
    bh = cfg.batch * cfg.n_heads
    cache_spec = jax.ShapeDtypeStruct(
        (cfg.n_layers, bh, cfg.max_len, cfg.head_dim), jnp.float32)
    tok1 = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)
    pos1 = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)
    lowered = jax.jit(
        lambda p, t, s, kc, vc: model_mod.decode_step(p, t, s, kc, vc, cfg)
    ).lower(pspec, tok1, pos1, cache_spec, cache_spec)
    path = os.path.join(out_dir, "tinylm_decode.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"wrote {path}")

    return {
        "vocab": cfg.vocab, "d_model": cfg.d_model, "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads, "d_ff": cfg.d_ff, "max_len": cfg.max_len,
        "batch": cfg.batch, "prefill_len": cfg.prefill_len,
        "head_dim": cfg.head_dim, "seed": seed,
        "params": [{"name": n, "shape": list(s)}
                   for n, s in model_mod.param_shapes(cfg)],
        "hlo_param_order": sorted_names,
    }


def export_selftest(out_dir: str, mcfg: ModelConfig, fcfg: ForecastConfig,
                    seed: int) -> None:
    """Golden outputs for the Rust PJRT round-trip test.

    Runs the *jitted jax* versions of the exported graphs on fixed inputs
    and records input + output samples; `rust/tests/pjrt_roundtrip.rs`
    executes the HLO artifacts on the same inputs and asserts allclose.
    """
    rng = np.random.default_rng(12345)
    params = model_mod.init_params(mcfg, seed=seed)

    tokens = rng.integers(0, mcfg.vocab,
                          size=(mcfg.batch, mcfg.prefill_len)).astype(np.int32)
    logits, kc, vc = jax.jit(
        lambda p, t: model_mod.prefill(p, t, mcfg))(params, jnp.asarray(tokens))
    last = np.asarray(logits[:, -1, :])
    nxt = np.argmax(last, axis=-1).astype(np.int32)
    pos = np.full((mcfg.batch,), mcfg.prefill_len, np.int32)
    dec_logits, _, _ = jax.jit(
        lambda p, t, s, k, v: model_mod.decode_step(p, t, s, k, v, mcfg)
    )(params, jnp.asarray(nxt), jnp.asarray(pos), kc, vc)

    t_axis = np.arange(fcfg.history)
    hist = np.stack([
        100.0 * (s + 1) * (1.0 + 0.5 * np.sin(2 * np.pi * t_axis / fcfg.season + s))
        for s in range(fcfg.n_series)
    ]).astype(np.float32)
    fc = fc_mod.forecast(jnp.asarray(hist), fcfg)

    blob = {
        "prefill_tokens": tokens.flatten().tolist(),
        "prefill_last_logits_head": last[:, :8].flatten().tolist(),
        "greedy_next": nxt.tolist(),
        "decode_logits_head": np.asarray(dec_logits)[:, :8].flatten().tolist(),
        "forecast_history": hist.flatten().tolist(),
        "forecast_out": np.asarray(fc).flatten().tolist(),
    }
    path = os.path.join(out_dir, "selftest.json")
    with open(path, "w") as f:
        json.dump(blob, f)
    print(f"wrote {path}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="legacy single-artifact path (ignored; kept for Make)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    mcfg, fcfg = ModelConfig(), ForecastConfig()
    tinylm = export_tinylm(out_dir, mcfg, args.seed)
    tinylm["shape_variants"] = export_tinylm_shape_variants(out_dir, mcfg)
    manifest = {
        "forecast": export_forecast(out_dir, fcfg),
        "tinylm": tinylm,
    }
    export_selftest(out_dir, mcfg, fcfg, args.seed)
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
