"""Layer-2 JAX model: the byte-level transformer LM the Rust coordinator serves.

This is the "small real model" of the end-to-end serving example
(examples/serve_model.rs): a GPT-style decoder-only transformer over a
byte vocabulary (256 symbols, tokenizer-free), whose attention runs through
the Layer-1 Pallas kernels (:mod:`kernels.attention`).

Two entry points are AOT-lowered to HLO text by :mod:`aot` and executed by
the Rust PJRT runtime — Python never runs at serve time:

* :func:`prefill` — process a padded prompt batch, return last-position
  logits plus the populated KV cache buffers.
* :func:`decode_step` — append one token per sequence, return next-token
  logits and updated caches.

Parameters are generated deterministically (:func:`init_params`), exported
as a flat little-endian f32 blob + JSON manifest (see :mod:`aot`), and fed
back in as runtime inputs by Rust in manifest order.  Weights as inputs
(not HLO constants) keeps the HLO text small and lets the same HLO serve
any checkpoint of the same shape.

Shape conventions are fixed at lowering time (continuous batching on the
Rust side maps requests onto batch lanes):
  B   batch lanes            S   prefill prompt length
  M   max sequence length (KV buffer)   L/H/D/F  layers/heads/model/ffn dims
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import mha_attention, mha_attention_decode
from .kernels.ref import layernorm_ref as _layernorm


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture hyper-parameters (fixed at AOT time)."""

    vocab: int = 256          # byte-level vocabulary
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    d_ff: int = 1024
    max_len: int = 256        # KV cache buffer length M
    batch: int = 8            # serving batch lanes B
    prefill_len: int = 128    # padded prompt length S

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# Parameter manifest order — Rust reads the blob in exactly this order.
def param_shapes(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Names and shapes of every parameter, in flat manifest order."""
    shapes: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_embed", (cfg.vocab, cfg.d_model)),
        ("pos_embed", (cfg.max_len, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        shapes += [
            (f"l{i}.ln1_g", (cfg.d_model,)),
            (f"l{i}.ln1_b", (cfg.d_model,)),
            (f"l{i}.wq", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wk", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wv", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wo", (cfg.d_model, cfg.d_model)),
            (f"l{i}.ln2_g", (cfg.d_model,)),
            (f"l{i}.ln2_b", (cfg.d_model,)),
            (f"l{i}.w1", (cfg.d_model, cfg.d_ff)),
            (f"l{i}.b1", (cfg.d_ff,)),
            (f"l{i}.w2", (cfg.d_ff, cfg.d_model)),
            (f"l{i}.b2", (cfg.d_model,)),
        ]
    shapes += [
        ("lnf_g", (cfg.d_model,)),
        ("lnf_b", (cfg.d_model,)),
        ("lm_head", (cfg.d_model, cfg.vocab)),
    ]
    return shapes


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Deterministic scaled-gaussian init (the 'checkpoint' we serve)."""
    rng = np.random.default_rng(seed)
    params: Dict[str, jnp.ndarray] = {}
    for name, shape in param_shapes(cfg):
        if name.endswith(("_g",)):
            arr = np.ones(shape, np.float32)
        elif name.endswith(("_b", ".b1", ".b2")):
            arr = np.zeros(shape, np.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            arr = rng.normal(0.0, fan_in ** -0.5, size=shape).astype(np.float32)
        params[name] = jnp.asarray(arr)
    return params


def params_spec(cfg: ModelConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for jit.lower — same pytree as init_params."""
    return {name: jax.ShapeDtypeStruct(shape, jnp.float32)
            for name, shape in param_shapes(cfg)}


def _gelu(x: jnp.ndarray) -> jnp.ndarray:
    # tanh-approx GELU: avoids erf, which keeps the lowered HLO free of
    # custom calls the bare PJRT CPU client cannot resolve.
    c = np.sqrt(2.0 / np.pi).astype(np.float32)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x ** 3)))


def _split_heads(x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """[B, T, D] -> [B*H, T, dh] (batch folded into the kernel head axis)."""
    b, t, _ = x.shape
    x = x.reshape(b, t, cfg.n_heads, cfg.head_dim)
    return x.transpose(0, 2, 1, 3).reshape(b * cfg.n_heads, t, cfg.head_dim)


def _merge_heads(x: jnp.ndarray, cfg: ModelConfig, batch: int) -> jnp.ndarray:
    """[B*H, T, dh] -> [B, T, D]."""
    t = x.shape[1]
    x = x.reshape(batch, cfg.n_heads, t, cfg.head_dim).transpose(0, 2, 1, 3)
    return x.reshape(batch, t, cfg.d_model)


def _block_prefill(params: Dict[str, Any], i: int, x: jnp.ndarray,
                   cfg: ModelConfig):
    """One transformer block over the full prompt; returns (x, k, v)."""
    p = lambda s: params[f"l{i}.{s}"]  # noqa: E731
    b = x.shape[0]
    h = _layernorm(x, p("ln1_g"), p("ln1_b"))
    q = _split_heads(h @ p("wq"), cfg)
    k = _split_heads(h @ p("wk"), cfg)
    v = _split_heads(h @ p("wv"), cfg)
    att = mha_attention(q, k, v, causal=True)
    x = x + _merge_heads(att, cfg, b) @ p("wo")
    h = _layernorm(x, p("ln2_g"), p("ln2_b"))
    x = x + _gelu(h @ p("w1") + p("b1")) @ p("w2") + p("b2")
    return x, k, v


def prefill(params: Dict[str, Any], tokens: jnp.ndarray, cfg: ModelConfig):
    """Prompt processing.

    Args:
      params: parameter dict (see :func:`param_shapes`).
      tokens: ``[B, S]`` int32 byte ids (right-padded; padding positions
        produce cache entries that decode masks away via ``kv_len``).

    Returns:
      ``(logits, k_cache, v_cache)`` where ``logits`` is ``[B, S, vocab]``
      (the Rust side picks the row at each prompt's true last position) and
      the caches are ``[L, B*H, M, dh]`` with positions ``>= S`` zeroed.
    """
    b, s = tokens.shape
    x = params["tok_embed"][tokens] + params["pos_embed"][None, :s, :]
    ks, vs = [], []
    for i in range(cfg.n_layers):
        x, k, v = _block_prefill(params, i, x, cfg)
        pad = cfg.max_len - s
        ks.append(jnp.pad(k, ((0, 0), (0, pad), (0, 0))))
        vs.append(jnp.pad(v, ((0, 0), (0, pad), (0, 0))))
    x = _layernorm(x, params["lnf_g"], params["lnf_b"])
    logits = x @ params["lm_head"]
    return logits, jnp.stack(ks), jnp.stack(vs)


def decode_step(params: Dict[str, Any], token: jnp.ndarray,
                pos: jnp.ndarray, k_cache: jnp.ndarray,
                v_cache: jnp.ndarray, cfg: ModelConfig):
    """Append one token per lane and predict the next.

    Args:
      token: ``[B]`` int32 — the token at position ``pos`` of each lane.
      pos: ``[B]`` int32 — where ``token`` goes in the cache (0-based).
      k_cache, v_cache: ``[L, B*H, M, dh]`` buffers from prefill/previous
        steps.

    Returns:
      ``(logits, k_cache, v_cache)`` — ``[B, vocab]`` next-token logits and
      updated caches.
    """
    b = token.shape[0]
    x = params["tok_embed"][token] + params["pos_embed"][pos]  # [B, D]
    x = x[:, None, :]  # [B, 1, D]
    # Per-(lane,head) valid length after inserting this token.
    kv_len = jnp.repeat(pos + 1, cfg.n_heads)  # [B*H]
    new_k, new_v = [], []
    for i in range(cfg.n_layers):
        p = lambda s: params[f"l{i}.{s}"]  # noqa: E731
        h = _layernorm(x, p("ln1_g"), p("ln1_b"))
        q = _split_heads(h @ p("wq"), cfg)             # [B*H, 1, dh]
        k_new = _split_heads(h @ p("wk"), cfg)
        v_new = _split_heads(h @ p("wv"), cfg)
        # Scatter this step's K/V rows into the cache at pos (per lane).
        rows = jnp.repeat(pos, cfg.n_heads)            # [B*H]
        k_i = _scatter_rows(k_cache[i], rows, k_new[:, 0, :])
        v_i = _scatter_rows(v_cache[i], rows, v_new[:, 0, :])
        new_k.append(k_i)
        new_v.append(v_i)
        att = mha_attention_decode(q, k_i, v_i, kv_len)
        x = x + _merge_heads(att, cfg, b) @ p("wo")
        h = _layernorm(x, p("ln2_g"), p("ln2_b"))
        x = x + _gelu(h @ p("w1") + p("b1")) @ p("w2") + p("b2")
    x = _layernorm(x, params["lnf_g"], params["lnf_b"])
    logits = (x @ params["lm_head"])[:, 0, :]
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def _scatter_rows(buf: jnp.ndarray, rows: jnp.ndarray,
                  vals: jnp.ndarray) -> jnp.ndarray:
    """Set ``buf[h, rows[h], :] = vals[h]`` for every head lane ``h``.

    Expressed as a one-hot masked blend (no scatter op) so the lowered HLO
    stays within the op set the bare PJRT CPU client executes fast.
    """
    n, m, _ = buf.shape
    onehot = (jnp.arange(m)[None, :] == rows[:, None]).astype(buf.dtype)
    return buf * (1.0 - onehot[:, :, None]) + onehot[:, :, None] * vals[:, None, :]


def reference_logits(params: Dict[str, Any], tokens: jnp.ndarray,
                     cfg: ModelConfig) -> jnp.ndarray:
    """Oracle: full-sequence logits via plain jnp attention (no Pallas, no
    cache) — used by pytest to validate prefill+decode consistency."""
    from .kernels.ref import attention_ref

    b, s = tokens.shape
    x = params["tok_embed"][tokens] + params["pos_embed"][None, :s, :]
    for i in range(cfg.n_layers):
        p = lambda t: params[f"l{i}.{t}"]  # noqa: E731
        h = _layernorm(x, p("ln1_g"), p("ln1_b"))
        q = _split_heads(h @ p("wq"), cfg)
        k = _split_heads(h @ p("wk"), cfg)
        v = _split_heads(h @ p("wv"), cfg)
        att = attention_ref(q, k, v, causal=True)
        x = x + _merge_heads(att, cfg, b) @ p("wo")
        h = _layernorm(x, p("ln2_g"), p("ln2_b"))
        x = x + _gelu(h @ p("w1") + p("b1")) @ p("w2") + p("b2")
    x = _layernorm(x, params["lnf_g"], params["lnf_b"])
    return x @ params["lm_head"]
