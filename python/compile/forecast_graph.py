"""Layer-2 JAX forecast graph: SageServe's hourly Load Predictor.

The paper forecasts next-hour input TPS per (model, region) with ARIMA
(§6.3).  We implement the equivalent *seasonal AR* pipeline as a single
AOT-compilable graph so the Rust Autoscaler calls one PJRT executable per
decision epoch:

  1. seasonal differencing  d[t] = y[t] - y[t-m]          (removes the
     diurnal cycle; m = periods per day),
  2. per-series AR(p) fit on d via conditioned least squares — the normal
     equations are solved with a hand-rolled ridge-regularized Gauss-Jordan
     (:func:`solve_spd`) because ``jnp.linalg.*`` lowers to LAPACK custom
     calls the bare PJRT CPU client cannot resolve,
  3. iterated H-step forecast of d via the Layer-1 Pallas kernel
     (:func:`kernels.ar_forecast`),
  4. seasonal re-integration  ŷ[T+h] = d̂[T+h] + y[T+h-m].

Inputs/outputs are pure arrays: ``history [S, T] -> forecast [S, H]`` with
S = n_models · n_regions series.  ``aot.py`` fixes (S, T, m, p, H) at
lowering time; the Rust side supplies the trailing window each epoch.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import ar_forecast


@dataclasses.dataclass(frozen=True)
class ForecastConfig:
    """Static shape/order parameters, fixed at AOT time."""

    n_series: int = 15     # models x regions
    history: int = 672     # T: trailing window length (7 days @ 15 min)
    season: int = 96       # m: periods per day (15-min resolution)
    order: int = 8         # p: AR order on the differenced series
    horizon: int = 4       # H: steps ahead (next hour @ 15 min)
    ridge: float = 1e-3    # Tikhonov weight in the normal equations


def solve_spd(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Solve ``a @ x = b`` for a batch of small SPD systems.

    Gauss-Jordan elimination without pivoting — valid because ``a`` is
    ridge-regularized SPD.  ``a: [S, n, n]``, ``b: [S, n]`` with n small
    (p+1); unrolled at trace time so the HLO is straight-line code.
    """
    s, n, _ = a.shape
    aug = jnp.concatenate([a, b[:, :, None]], axis=2)  # [S, n, n+1]
    for col in range(n):
        pivot = aug[:, col, col][:, None]              # [S, 1]
        row = aug[:, col, :] / pivot                   # [S, n+1]
        aug = aug.at[:, col, :].set(row)
        factors = aug[:, :, col]                       # [S, n]
        factors = factors.at[:, col].set(0.0)          # skip the pivot row
        aug = aug - factors[:, :, None] * row[:, None, :]
    return aug[:, :, n]


def fit_ar(diff: jnp.ndarray, order: int, ridge: float):
    """Conditioned-least-squares AR(p) fit for a batch of series.

    Args:
      diff: ``[S, Td]`` differenced series (time ascending).
      order: AR order p.
      ridge: Tikhonov regularizer (also guards near-constant series).

    Returns:
      ``(coefs [S, p], intercept [S])`` with ``coefs[:, 0]`` on the newest
      lag, matching the Layer-1 kernel convention.
    """
    s, td = diff.shape
    rows = td - order
    # Design matrix X[t, i] = d[t + order - 1 - i]  (lag i+1), target y[t] =
    # d[t + order].  Built with static slices: stack p shifted views.
    x = jnp.stack([diff[:, order - 1 - i:td - 1 - i] for i in range(order)],
                  axis=2)                              # [S, rows, p]
    y = diff[:, order:]                                # [S, rows]
    ones = jnp.ones((s, rows, 1), diff.dtype)
    xa = jnp.concatenate([x, ones], axis=2)            # [S, rows, p+1]
    gram = jnp.einsum("sri,srj->sij", xa, xa)
    gram = gram + ridge * jnp.eye(order + 1, dtype=diff.dtype)[None]
    rhs = jnp.einsum("sri,sr->si", xa, y)
    beta = solve_spd(gram, rhs)                        # [S, p+1]
    return beta[:, :order], beta[:, order]


@functools.partial(jax.jit, static_argnames=("cfg",))
def forecast(history: jnp.ndarray, cfg: ForecastConfig) -> jnp.ndarray:
    """End-to-end load forecast: ``[S, T] -> [S, H]`` (clamped at >= 0)."""
    s, t = history.shape
    assert s == cfg.n_series and t == cfg.history, (history.shape, cfg)
    m, p, h = cfg.season, cfg.order, cfg.horizon
    assert h <= m, "re-integration below assumes horizon within one season"

    diff = history[:, m:] - history[:, :-m]            # [S, T-m]
    coefs, icept = fit_ar(diff, p, cfg.ridge)
    recent = diff[:, -p:]                              # newest last
    dhat = ar_forecast(recent, coefs, icept, horizon=h)  # [S, H] (L1 kernel)
    # ŷ[T+i] = d̂[T+i] + y[T+i-m] for i = 1..H  (H <= m ⇒ base is observed).
    base = history[:, t - m:t - m + h]
    return jnp.maximum(dhat + base, 0.0)


def forecast_ref(history: jnp.ndarray, cfg: ForecastConfig) -> jnp.ndarray:
    """Oracle: same pipeline with the pure-jnp AR recursion (no Pallas)."""
    from .kernels.ref import ar_forecast_ref

    m, p, h = cfg.season, cfg.order, cfg.horizon
    t = history.shape[1]
    diff = history[:, m:] - history[:, :-m]
    coefs, icept = fit_ar(diff, p, cfg.ridge)
    dhat = ar_forecast_ref(diff[:, -p:], coefs, icept, h)
    base = history[:, t - m:t - m + h]
    return jnp.maximum(dhat + base, 0.0)
