"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

This file is the CORE correctness signal for the compute layer.  Fixed-case
tests pin down the exact serving shapes; hypothesis sweeps shapes, dtypes
and block sizes.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import mha_attention, mha_attention_decode, ar_forecast
from compile.kernels.ref import (
    attention_ref,
    attention_decode_ref,
    ar_forecast_ref,
)

RNG = np.random.default_rng(1234)


def _randn(*shape, scale=1.0, dtype=np.float32):
    return jnp.asarray(RNG.normal(0, scale, size=shape).astype(dtype))


# ---------------------------------------------------------------------------
# prefill attention kernel
# ---------------------------------------------------------------------------

class TestAttentionPrefill:
    def test_serving_shape(self):
        """The exact (heads, seq, dim) used by the AOT'd prefill graph."""
        q, k, v = (_randn(64, 128, 32) for _ in range(3))
        out = mha_attention(q, k, v, causal=True)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_non_causal(self):
        q, k, v = (_randn(4, 64, 64) for _ in range(3))
        out = mha_attention(q, k, v, causal=False)
        ref = attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_multiple_q_blocks(self):
        """seq_q spanning several q tiles exercises the grid index math."""
        q, k, v = (_randn(2, 256, 32) for _ in range(3))
        out = mha_attention(q, k, v, causal=True, block_q=64, block_k=64)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_rect_kv_longer_than_q(self):
        """seq_k > seq_q aligns the causal diagonal to the key end."""
        q = _randn(2, 64, 32)
        k, v = _randn(2, 128, 32), _randn(2, 128, 32)
        out = mha_attention(q, k, v, causal=True, block_q=64, block_k=64)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_single_query_row(self):
        q = _randn(8, 1, 64)
        k, v = _randn(8, 128, 64), _randn(8, 128, 64)
        out = mha_attention(q, k, v, causal=True)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_large_logits_stable(self):
        """Online softmax must not overflow for large-magnitude logits."""
        rng = np.random.default_rng(42)
        q, k, v = (jnp.asarray(rng.normal(0, 30.0, (2, 64, 32)), jnp.float32)
                   for _ in range(3))
        out = mha_attention(q, k, v, causal=True)
        assert bool(jnp.isfinite(out).all())
        ref = attention_ref(q, k, v, causal=True)
        # With |logits| ~ O(1e3) a one-ulp difference in the running max
        # shifts exp() noticeably; 1e-3 relative is the honest bound here.
        np.testing.assert_allclose(out, ref, atol=1e-3, rtol=1e-3)

    def test_rejects_misaligned_blocks(self):
        q, k, v = (_randn(2, 100, 32) for _ in range(3))
        with pytest.raises(ValueError):
            mha_attention(q, k, v, block_q=64, block_k=64)

    @settings(max_examples=20, deadline=None)
    @given(
        heads=st.integers(1, 4),
        dim=st.sampled_from([16, 32, 64]),
        q_blocks=st.integers(1, 3),
        k_extra=st.integers(0, 2),
        block=st.sampled_from([32, 64]),
        causal=st.booleans(),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, heads, dim, q_blocks, k_extra, block,
                               causal, seed):
        rng = np.random.default_rng(seed)
        seq_q = q_blocks * block
        seq_k = seq_q + k_extra * block
        q = jnp.asarray(rng.normal(size=(heads, seq_q, dim)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(heads, seq_k, dim)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(heads, seq_k, dim)), jnp.float32)
        out = mha_attention(q, k, v, causal=causal, block_q=block, block_k=block)
        ref = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(out, ref, atol=5e-5, rtol=5e-5)


# ---------------------------------------------------------------------------
# decode attention kernel
# ---------------------------------------------------------------------------

class TestAttentionDecode:
    def test_serving_shape(self):
        """Exact decode shape from the AOT'd graph: B*H=64 lanes, M=256."""
        q = _randn(64, 1, 32)
        k, v = _randn(64, 256, 32), _randn(64, 256, 32)
        lens = jnp.asarray(RNG.integers(1, 257, size=64), jnp.int32)
        out = mha_attention_decode(q, k, v, lens)
        ref = attention_decode_ref(q, k, v, lens)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_len_one(self):
        """A sequence that has seen exactly one token attends only to it."""
        q = _randn(4, 1, 16)
        k, v = _randn(4, 64, 16), _randn(4, 64, 16)
        lens = jnp.ones((4,), jnp.int32)
        out = mha_attention_decode(q, k, v, lens)
        np.testing.assert_allclose(out[:, 0, :], v[:, 0, :], atol=2e-5,
                                   rtol=2e-5)

    def test_full_buffer(self):
        q = _randn(4, 1, 16)
        k, v = _randn(4, 64, 16), _randn(4, 64, 16)
        lens = jnp.full((4,), 64, jnp.int32)
        out = mha_attention_decode(q, k, v, lens)
        ref = attention_decode_ref(q, k, v, lens)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_garbage_beyond_len_ignored(self):
        """Poison the invalid cache slots; output must not change."""
        q = _randn(4, 1, 16)
        k, v = _randn(4, 64, 16), _randn(4, 64, 16)
        lens = jnp.full((4,), 10, jnp.int32)
        base = mha_attention_decode(q, k, v, lens)
        k2 = k.at[:, 10:, :].set(1e9)
        v2 = v.at[:, 10:, :].set(-1e9)
        poisoned = mha_attention_decode(q, k2, v2, lens)
        np.testing.assert_allclose(base, poisoned, atol=2e-5, rtol=2e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        heads=st.integers(1, 8),
        dim=st.sampled_from([16, 32]),
        max_blocks=st.integers(1, 4),
        block=st.sampled_from([32, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_lengths(self, heads, dim, max_blocks, block, seed):
        rng = np.random.default_rng(seed)
        max_len = max_blocks * block
        q = jnp.asarray(rng.normal(size=(heads, 1, dim)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(heads, max_len, dim)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(heads, max_len, dim)), jnp.float32)
        lens = jnp.asarray(rng.integers(1, max_len + 1, size=heads), jnp.int32)
        out = mha_attention_decode(q, k, v, lens, block_k=block)
        ref = attention_decode_ref(q, k, v, lens)
        np.testing.assert_allclose(out, ref, atol=5e-5, rtol=5e-5)


# ---------------------------------------------------------------------------
# AR forecast kernel
# ---------------------------------------------------------------------------

class TestARForecast:
    def test_serving_shape(self):
        """The exact (series, order, horizon) used by the AOT'd graph."""
        s, p, h = 15, 8, 4
        hist = _randn(s, p, scale=10.0)
        coef = _randn(s, p, scale=0.2)
        icept = _randn(s)
        out = ar_forecast(hist, coef, icept, horizon=h)
        ref = ar_forecast_ref(hist, coef, icept, h)
        np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)

    def test_horizon_one_is_dot_product(self):
        hist = _randn(3, 4)
        coef = _randn(3, 4, scale=0.3)
        icept = _randn(3)
        out = ar_forecast(hist, coef, icept, horizon=1)
        expect = icept + jnp.sum(coef * hist[:, ::-1], axis=1)
        np.testing.assert_allclose(out[:, 0], expect, atol=1e-5, rtol=1e-5)

    def test_ar1_closed_form(self):
        """AR(1) with coefficient a: y[h] = a^h y0 + c (1-a^h)/(1-a)."""
        a, c, y0 = 0.5, 2.0, 10.0
        hist = jnp.asarray([[y0]], jnp.float32)
        coef = jnp.asarray([[a]], jnp.float32)
        icept = jnp.asarray([c], jnp.float32)
        out = np.asarray(ar_forecast(hist, coef, icept, horizon=5))[0]
        expect = [a ** h * y0 + c * (1 - a ** h) / (1 - a)
                  for h in range(1, 6)]
        np.testing.assert_allclose(out, expect, atol=1e-4, rtol=1e-4)

    def test_series_padding(self):
        """Series counts that do not divide block_s are padded internally."""
        s, p, h = 7, 4, 3
        hist, coef, icept = _randn(s, p), _randn(s, p, scale=0.2), _randn(s)
        out = ar_forecast(hist, coef, icept, horizon=h, block_s=4)
        ref = ar_forecast_ref(hist, coef, icept, h)
        np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)

    def test_shape_validation(self):
        with pytest.raises(ValueError):
            ar_forecast(_randn(3, 4), _randn(3, 5), _randn(3), horizon=2)
        with pytest.raises(ValueError):
            ar_forecast(_randn(3, 4), _randn(3, 4), _randn(4), horizon=2)

    @settings(max_examples=25, deadline=None)
    @given(
        series=st.integers(1, 40),
        order=st.integers(1, 12),
        horizon=st.integers(1, 16),
        block=st.sampled_from([4, 8, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, series, order, horizon, block, seed):
        rng = np.random.default_rng(seed)
        hist = jnp.asarray(rng.normal(0, 10, (series, order)), jnp.float32)
        # Keep the companion matrix stable so iterated forecasts don't blow
        # past f32 range for large horizons.
        coef = jnp.asarray(rng.normal(0, 0.9 / order, (series, order)),
                           jnp.float32)
        icept = jnp.asarray(rng.normal(0, 1, (series,)), jnp.float32)
        out = ar_forecast(hist, coef, icept, horizon=horizon, block_s=block)
        ref = ar_forecast_ref(hist, coef, icept, horizon)
        np.testing.assert_allclose(out, ref, atol=1e-3, rtol=1e-3)
