"""Layer-2 forecast graph: SPD solver, AR fit, end-to-end forecast quality."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import forecast_graph as F

RNG = np.random.default_rng(99)


class TestSolveSpd:
    def test_matches_numpy(self):
        n, s = 9, 5
        a = RNG.normal(size=(s, n, n)).astype(np.float32)
        a = a @ a.transpose(0, 2, 1) + 0.5 * np.eye(n, dtype=np.float32)
        b = RNG.normal(size=(s, n)).astype(np.float32)
        x = F.solve_spd(jnp.asarray(a), jnp.asarray(b))
        expect = np.stack([np.linalg.solve(a[i], b[i]) for i in range(s)])
        np.testing.assert_allclose(x, expect, atol=1e-3, rtol=1e-3)

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(1, 10), s=st.integers(1, 8),
           seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_spd(self, n, s, seed):
        rng = np.random.default_rng(seed)
        a = rng.normal(size=(s, n, n)).astype(np.float32)
        a = a @ a.transpose(0, 2, 1) + np.eye(n, dtype=np.float32)
        b = rng.normal(size=(s, n)).astype(np.float32)
        x = np.asarray(F.solve_spd(jnp.asarray(a), jnp.asarray(b)))
        residual = np.einsum("sij,sj->si", a, x) - b
        assert np.abs(residual).max() < 1e-2


class TestFitAr:
    def test_recovers_known_ar2(self):
        """Fit on a synthetic AR(2) series; coefficients must be recovered."""
        a1, a2, c = 0.6, -0.3, 1.5
        t = 800
        y = np.zeros(t, np.float64)
        noise = RNG.normal(0, 0.05, t)
        for i in range(2, t):
            y[i] = c + a1 * y[i - 1] + a2 * y[i - 2] + noise[i]
        diff = jnp.asarray(y[None, :], jnp.float32)
        coefs, icept = F.fit_ar(diff, order=2, ridge=1e-4)
        assert abs(float(coefs[0, 0]) - a1) < 0.05   # newest lag
        assert abs(float(coefs[0, 1]) - a2) < 0.05
        assert abs(float(icept[0]) - c) < 0.2

    def test_constant_series_stable(self):
        """Ridge keeps the normal equations solvable for constant series."""
        diff = jnp.ones((3, 100), jnp.float32) * 5.0
        coefs, icept = F.fit_ar(diff, order=4, ridge=1e-3)
        assert bool(jnp.isfinite(coefs).all()) and bool(jnp.isfinite(icept).all())
        # One-step prediction should still be ~5.
        pred = icept + jnp.sum(coefs * 5.0, axis=1)
        np.testing.assert_allclose(pred, 5.0, atol=0.2)


class TestForecast:
    CFG = F.ForecastConfig(n_series=4, history=672, season=96, order=8,
                           horizon=4)

    def _diurnal(self, n, extra=0):
        t = np.arange(self.CFG.history + extra)
        out = []
        for s in range(n):
            y = 50 * (s + 1) * (1 + 0.6 * np.sin(2 * np.pi * t / 96 + s))
            out.append(y + RNG.normal(0, 2, t.shape))
        return np.stack(out).astype(np.float32)

    def test_kernel_path_matches_ref(self):
        hist = jnp.asarray(self._diurnal(4))
        out = F.forecast(hist, self.CFG)
        ref = F.forecast_ref(hist, self.CFG)
        np.testing.assert_allclose(out, ref, atol=1e-2, rtol=1e-3)

    def test_diurnal_accuracy(self):
        """MAPE < 10% on clean diurnal traffic (paper: ARIMA is 'accurate
        enough to forecast the diurnal load')."""
        ys = self._diurnal(4, extra=self.CFG.horizon)
        hist = jnp.asarray(ys[:, :self.CFG.history])
        fc = np.asarray(F.forecast(hist, self.CFG))
        true = ys[:, self.CFG.history:]
        mape = np.abs((fc - true) / np.maximum(true, 1.0)).mean()
        assert mape < 0.10, mape

    def test_non_negative(self):
        """TPS forecasts are clamped at zero even for crashing series."""
        t = np.arange(self.CFG.history)
        y = np.maximum(1000.0 - 2.0 * t, 0.0)
        hist = jnp.asarray(np.tile(y, (4, 1)), jnp.float32)
        fc = F.forecast(hist, self.CFG)
        assert float(fc.min()) >= 0.0

    def test_shape_contract(self):
        hist = jnp.asarray(self._diurnal(4))
        fc = F.forecast(hist, self.CFG)
        assert fc.shape == (4, self.CFG.horizon)

    def test_wrong_shape_raises(self):
        with pytest.raises(AssertionError):
            F.forecast(jnp.zeros((3, 100), jnp.float32), self.CFG)
