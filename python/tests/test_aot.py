"""AOT export path: HLO text generation sanity (fast — no full export).

The full `make artifacts` round-trip (including numerics vs the Rust PJRT
runtime) is covered by `sageserve selftest` / rust/tests/pjrt_roundtrip.rs;
these tests pin the pieces that must hold for that bridge to exist at all.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile.forecast_graph import ForecastConfig, forecast


TINY = M.ModelConfig(d_model=32, n_layers=1, n_heads=2, d_ff=64,
                     max_len=16, batch=2, prefill_len=8)


def test_to_hlo_text_produces_parseable_module():
    lowered = jax.jit(lambda x: (x @ x + 1.0,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:60]
    assert "ENTRY" in text
    # return_tuple=True: root computation returns a tuple type.
    assert "(f32[4,4]" in text


def test_hlo_text_has_no_custom_calls():
    """The bare PJRT CPU client cannot resolve jaxlib custom calls; the
    exported graphs must avoid them (that's why solve_spd and tanh-GELU
    exist)."""
    params = M.params_spec(TINY)
    toks = jax.ShapeDtypeStruct((TINY.batch, TINY.prefill_len), jnp.int32)
    lowered = jax.jit(lambda p, t: M.prefill(p, t, TINY)).lower(params, toks)
    text = aot.to_hlo_text(lowered)
    assert "custom-call" not in text, "prefill HLO contains custom calls"

    fcfg = ForecastConfig(n_series=2, history=200, season=96, order=4, horizon=4)
    hist = jax.ShapeDtypeStruct((2, 200), jnp.float32)
    lowered = jax.jit(lambda h: (forecast(h, fcfg),)).lower(hist)
    text = aot.to_hlo_text(lowered)
    assert "custom-call" not in text, "forecast HLO contains custom calls"


def test_param_manifest_matches_flattened_params():
    """Weights blob order (param_shapes) and HLO argument order (sorted
    names) must both be derivable from the manifest — the Rust loader
    depends on it."""
    names = [n for n, _ in M.param_shapes(TINY)]
    assert len(names) == len(set(names)), "duplicate param names"
    params = M.init_params(TINY, seed=0)
    assert set(params.keys()) == set(names)
    # jax flattens dicts in sorted-key order; that's what aot.py records.
    leaves, _ = jax.tree_util.tree_flatten(params)
    by_sorted = [np.asarray(params[k]) for k in sorted(names)]
    assert len(leaves) == len(by_sorted)
    for a, b in zip(leaves, by_sorted):
        np.testing.assert_array_equal(np.asarray(a), b)


@pytest.mark.parametrize("seed", [0, 1])
def test_weights_blob_roundtrip(tmp_path, seed):
    cfg = TINY
    params = M.init_params(cfg, seed=seed)
    blob = tmp_path / "params.bin"
    with open(blob, "wb") as f:
        for name, _ in M.param_shapes(cfg):
            np.asarray(params[name], dtype="<f4").tofile(f)
    raw = np.fromfile(blob, dtype="<f4")
    offset = 0
    for name, shape in M.param_shapes(cfg):
        n = int(np.prod(shape))
        got = raw[offset:offset + n].reshape(shape)
        np.testing.assert_array_equal(got, np.asarray(params[name]))
        offset += n
    assert offset == raw.size
