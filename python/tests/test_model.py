"""Layer-2 transformer: prefill/decode consistency against the no-cache oracle."""

import numpy as np
import pytest
import jax.numpy as jnp

from compile import model as M

RNG = np.random.default_rng(7)

SMALL = M.ModelConfig(d_model=64, n_layers=2, n_heads=4, d_ff=128,
                      max_len=32, batch=2, prefill_len=16)


@pytest.fixture(scope="module")
def params():
    return M.init_params(SMALL, seed=3)


def _tokens(b, s):
    return jnp.asarray(RNG.integers(0, SMALL.vocab, size=(b, s)), jnp.int32)


class TestPrefill:
    def test_matches_reference(self, params):
        toks = _tokens(2, 16)
        logits, _k, _v = M.prefill(params, toks, SMALL)
        ref = M.reference_logits(params, toks, SMALL)
        np.testing.assert_allclose(logits, ref, atol=1e-4, rtol=1e-4)

    def test_cache_shapes(self, params):
        toks = _tokens(2, 16)
        _, k, v = M.prefill(params, toks, SMALL)
        bh = SMALL.batch * SMALL.n_heads
        assert k.shape == (SMALL.n_layers, bh, SMALL.max_len, SMALL.head_dim)
        assert v.shape == k.shape

    def test_cache_zero_beyond_prompt(self, params):
        toks = _tokens(2, 16)
        _, k, v = M.prefill(params, toks, SMALL)
        assert float(jnp.abs(k[:, :, 16:, :]).max()) == 0.0
        assert float(jnp.abs(v[:, :, 16:, :]).max()) == 0.0

    def test_batch_lanes_independent(self, params):
        """Changing lane 1's prompt must not change lane 0's logits."""
        toks = _tokens(2, 16)
        l1, _, _ = M.prefill(params, toks, SMALL)
        toks2 = toks.at[1].set((toks[1] + 17) % SMALL.vocab)
        l2, _, _ = M.prefill(params, toks2, SMALL)
        np.testing.assert_allclose(l1[0], l2[0], atol=1e-5, rtol=1e-5)
        assert float(jnp.abs(l1[1] - l2[1]).max()) > 1e-3


class TestDecode:
    def test_one_step_matches_full_forward(self, params):
        toks = _tokens(2, 16)
        _, kc, vc = M.prefill(params, toks, SMALL)
        nxt = _tokens(2, 1)[:, 0]
        pos = jnp.full((2,), 16, jnp.int32)
        dl, _, _ = M.decode_step(params, nxt, pos, kc, vc, SMALL)
        full = jnp.concatenate([toks, nxt[:, None]], axis=1)
        ref = M.reference_logits(params, full, SMALL)
        np.testing.assert_allclose(dl, ref[:, -1, :], atol=1e-4, rtol=1e-4)

    def test_multi_step_chain(self, params):
        """Greedy-decode 6 steps via the cache; must equal full forwards."""
        toks = _tokens(2, 16)
        logits, kc, vc = M.prefill(params, toks, SMALL)
        cur = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        seq = toks
        for step in range(6):
            pos = jnp.full((2,), 16 + step, jnp.int32)
            dl, kc, vc = M.decode_step(params, cur, pos, kc, vc, SMALL)
            seq = jnp.concatenate([seq, cur[:, None]], axis=1)
            ref = M.reference_logits(params, seq, SMALL)
            np.testing.assert_allclose(dl, ref[:, -1, :], atol=2e-4, rtol=2e-4)
            cur = jnp.argmax(dl, axis=-1).astype(jnp.int32)

    def test_ragged_positions(self, params):
        """Lanes at different sequence lengths decode independently."""
        toks = _tokens(2, 16)
        _, kc, vc = M.prefill(params, toks, SMALL)
        # lane 0 continues at position 16; lane 1 pretends its prompt was
        # only 8 tokens long (cache rows 8..16 are stale but masked).
        nxt = _tokens(2, 1)[:, 0]
        pos = jnp.asarray([16, 8], jnp.int32)
        dl, _, _ = M.decode_step(params, nxt, pos, kc, vc, SMALL)
        short = jnp.concatenate([toks[1:2, :8], nxt[1:2, None]], axis=1)
        ref = M.reference_logits(params, short, SMALL)
        np.testing.assert_allclose(dl[1], ref[0, -1, :], atol=1e-4, rtol=1e-4)


class TestParams:
    def test_manifest_order_deterministic(self):
        a = [n for n, _ in M.param_shapes(SMALL)]
        b = [n for n, _ in M.param_shapes(SMALL)]
        assert a == b

    def test_init_deterministic(self):
        p1 = M.init_params(SMALL, seed=11)
        p2 = M.init_params(SMALL, seed=11)
        for k in p1:
            np.testing.assert_array_equal(p1[k], p2[k])

    def test_param_count(self):
        total = sum(int(np.prod(s)) for _, s in M.param_shapes(M.ModelConfig()))
        # ~3.35M parameters for the default serving config.
        assert 3_000_000 < total < 4_000_000
